"""MedVerse Curator walkthrough: inspect every phase on one question.

Run:  PYTHONPATH=src python examples/curate_data.py
"""

from repro.data import Curator, build_kg, generate_qa


def main():
    kg = build_kg(n_synthetic_clusters=24, seed=0)
    print(f"KG: {len(kg.entities)} entities, {len(kg.edges)} edges")
    item = generate_qa(kg, 8, seed=1)[0]
    print(f"\nQ: {item.question}")
    print(f"options: {item.options}  gold: {item.answer_letter}")

    cur = Curator(kg)
    raw = cur.retrieve_paths(item)
    print(f"\nPhase 1 — retrieval: {len(raw)} raw KG paths, e.g.")
    for p in raw[:3]:
        print("   ", " -> ".join(p))

    filtered = cur.filter_paths(raw, item)
    print(f"\nPhase 2 — filtering: kept {len(filtered)} "
          f"(relevance+dedup+cap rules)")
    dag, meta = cur.consolidate(filtered)
    print(f"   consolidated DAG: {len(dag.nodes)} transitions, "
          f"depth {dag.depth()}, layers {dag.topological_layers()}")

    ex = cur.synthesize(item, dag, meta, filtered)
    print(f"\nPhase 3 — synthesis ({ex.topology}):")
    print("   plan:", ex.plan.serialize()[:260], "...")
    first = sorted(ex.step_texts)[0]
    print("   step:", ex.step_texts[first][:160], "...")
    print("   conclusion:", ex.conclusion_text[:160])

    ok, why = cur.verify(ex, item)
    print(f"\nPhase 4 — dual-layer verification: {ok} ({why})")
    print("\ncurator stats:", cur.stats)


if __name__ == "__main__":
    main()
