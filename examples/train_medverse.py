"""Training driver: curate -> train a MedVerse model -> checkpoint ->
evaluate plan validity. Defaults to a CPU-scale model; ``--full``
selects a ~100M-parameter config (the same code path the production
launcher shards with pjit — see repro/launch/train.py).

Run:  PYTHONPATH=src python examples/train_medverse.py [--full]
"""

import argparse
import os
import time

from repro.data import Corpus
from repro.engine import MedVerseEngine, EngineConfig
from repro.models.config import ATTN, ModelConfig
from repro.train import TrainConfig, save_checkpoint, train_model


def model_config(vocab: int, full: bool) -> ModelConfig:
    if full:  # ~100M params
        return ModelConfig(
            name="medverse-100m", arch_type="dense", vocab_size=vocab,
            d_model=768, n_layers=12, n_heads=12, n_kv_heads=4,
            d_ff=2048, head_dim=64, pattern_unit=(ATTN,),
            dtype="float32", max_seq_len=1024)
    return ModelConfig(
        name="medverse-mini", arch_type="dense", vocab_size=vocab,
        d_model=192, n_layers=4, n_heads=4, n_kv_heads=2, d_ff=512,
        head_dim=48, pattern_unit=(ATTN,), dtype="float32",
        scan_layers=False, remat=False, max_seq_len=1024)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--items", type=int, default=400)
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--out", default="results/medverse_model.ckpt")
    args = ap.parse_args()

    print("== curating ==")
    corpus = Corpus.build(n_items=args.items, n_clusters=48)
    print(f"   {len(corpus.train)} train examples, "
          f"vocab {corpus.tokenizer.vocab_size}")
    cfg = model_config(corpus.tokenizer.vocab_size + 64, args.full)
    n_params = cfg.param_count()
    print(f"== training {cfg.name} ({n_params/1e6:.1f}M params, "
          f"{args.epochs} epochs) ==")
    t0 = time.time()
    params, hist = train_model(
        cfg, corpus,
        TrainConfig(epochs=args.epochs, batch_size=8, seq_len=256))
    print(f"   {time.time()-t0:.0f}s; ce {hist[0]['ce']:.2f} -> "
          f"{hist[-1]['ce']:.2f}")
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    save_checkpoint(args.out, params, step=len(hist),
                    metadata={"arch": cfg.name})
    corpus.tokenizer.save(args.out + ".vocab.json")
    print(f"   checkpoint -> {args.out}")

    print("== plan-validity probe (Phase I end-to-end) ==")
    eng = MedVerseEngine(params, cfg, corpus.tokenizer,
                         EngineConfig(max_slots=4, n_pages=4096,
                                      max_chain_len=512))
    exs = corpus.eval[:4]
    prompts = [f"{e.question} Options : "
               + " ".join(f"{l} ) {o}" for l, o in zip("abcd", e.options))
               for e in exs]
    res = eng.generate(prompts)
    print(f"   plan_ok {sum(r.plan_ok for r in res)}/{len(res)}")


if __name__ == "__main__":
    main()
