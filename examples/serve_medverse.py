"""End-to-end serving driver: batched medical questions through the
MedVerse Engine with continuous batching — the paper-kind (inference)
end-to-end example.

Trains (or loads) a small model on the synthetic corpus, then serves a
batch of eval questions: Phase I planning, Phase II frontier-parallel
execution, conclusions; prints per-request structure + aggregate
latency/throughput vs the serial baseline.

Run:  PYTHONPATH=src:. python examples/serve_medverse.py [--batch 8]
"""

import argparse
import time

from benchmarks.common import default_engine_cfg, extract_answer, get_artifacts
from repro.engine import MedVerseEngine, SerialEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=6)
    ap.add_argument("--model-plans", action="store_true",
                    help="let the model plan (Phase I) instead of "
                    "injecting curated plans")
    args = ap.parse_args()

    art = get_artifacts()
    tok = art.corpus.tokenizer
    exs = art.corpus.eval[: args.batch]
    prompts, plans, golds = [], [], []
    for ex in exs:
        opts = " ".join(f"{l} ) {o}" for l, o in zip("abcd", ex.options))
        p = f"{ex.question} Options : {opts}"
        prompts.append(p)
        plans.append(ex.prefix_text[len(p):].strip())
        golds.append(ex.answer_letter)

    print(f"== serving {len(prompts)} requests (continuous batching) ==")
    results = []
    t0 = time.time()
    if args.model_plans:
        eng = MedVerseEngine(art.params_mask, art.cfg, tok,
                             default_engine_cfg(max_slots=8))
        results = eng.generate(prompts)
    else:
        eng = MedVerseEngine(art.params_mask, art.cfg, tok,
                             default_engine_cfg(max_slots=8))
        results = eng.generate(prompts, plans=plans)
    par_wall = time.time() - t0
    n_tok = sum(r.n_tokens for r in results)
    print(f"parallel: {par_wall:.1f}s, {n_tok} tokens, "
          f"{n_tok/par_wall:.1f} tok/s")
    for r, g in zip(results, golds):
        a = extract_answer(r.text)
        print(f"  plan_ok={r.plan_ok} topo={r.topology:<28} "
              f"steps={len(r.step_texts)} crit={r.critical_path_tokens:>4} "
              f"ans={a} gold={g} {'OK' if a == g else ''}")

    ser = SerialEngine(art.params_auto, art.cfg, tok, default_engine_cfg())
    t0 = time.time()
    ser.generate(prompts, max_tokens=max(n_tok // len(prompts), 16))
    ser_wall = time.time() - t0
    print(f"serial baseline (iso-tokens): {ser_wall:.1f}s  "
          f"-> speedup {ser_wall/par_wall:.2f}x")


if __name__ == "__main__":
    main()
