"""Quickstart: the whole MedVerse stack in one minute on CPU.

  1. Build a synthetic medical KG and curate a small structured corpus
     (MedVerse Curator, 4 phases).
  2. Fine-tune a tiny decoder with MedVerse attention (DAG mask +
     adaptive positions).
  3. Serve a question through the MedVerse Engine: linear planning ->
     Petri-net frontier execution with Fork/Join -> conclusion.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

from repro.data import Corpus
from repro.engine import EngineConfig, MedVerseEngine, SerialEngine
from repro.models.config import ATTN, ModelConfig
from repro.train import TrainConfig, train_model


def main():
    print("== 1. Curating synthetic MedVerse corpus ==")
    corpus = Corpus.build(n_items=120, n_clusters=24, seed=0)
    print(f"   {len(corpus.train)} train / {len(corpus.eval)} eval examples,"
          f" vocab={corpus.tokenizer.vocab_size}")

    print("== 2. Training a tiny MedVerse model (DAG attention) ==")
    cfg = ModelConfig(
        name="quickstart", arch_type="dense",
        vocab_size=corpus.tokenizer.vocab_size + 32,
        d_model=128, n_layers=2, n_heads=4, n_kv_heads=2, d_ff=256,
        head_dim=32, pattern_unit=(ATTN,), dtype="float32",
        scan_layers=False, remat=False, max_seq_len=512,
    )
    t0 = time.time()
    params, hist = train_model(
        cfg, corpus, TrainConfig(epochs=2, batch_size=8, seq_len=256))
    print(f"   trained {len(hist)} logged steps in {time.time()-t0:.0f}s; "
          f"ce {hist[0]['ce']:.2f} -> {hist[-1]['ce']:.2f}")

    print("== 3. Serving through the MedVerse Engine ==")
    ex = corpus.eval[0]
    opts = " ".join(f"{l} ) {o}" for l, o in zip("abcd", ex.options))
    prompt = f"{ex.question} Options : {opts}"
    plan = ex.prefix_text[len(prompt):].strip()  # inject a curated plan
    eng = MedVerseEngine(params, cfg, corpus.tokenizer,
                         EngineConfig(max_slots=8, page_size=8,
                                      n_pages=2048, max_chain_len=384,
                                      max_step_tokens=16,
                                      max_conclusion_tokens=16,
                                      plan_override=plan))
    eng.generate([prompt])  # warm the jit caches before timing
    t0 = time.time()
    res = eng.generate([prompt])[0]
    print(f"   topology={res.topology}  steps={len(res.step_texts)}  "
          f"tokens={res.n_tokens}  critical_path={res.critical_path_tokens}")
    print(f"   parallel wall: {time.time()-t0:.2f}s "
          f"(fork/join cost {res.timings['fork_join']*1e3:.1f}ms, "
          f"scheduling {res.timings['schedule_parse']*1e3:.1f}ms)")
    ser = SerialEngine(params, cfg, corpus.tokenizer,
                       EngineConfig(max_slots=8, page_size=8, n_pages=2048,
                                    max_chain_len=384))
    ser.generate([prompt], max_tokens=4)  # warm
    t0 = time.time()
    ser.generate([prompt], max_tokens=res.n_tokens)
    print(f"   serial wall (same token count): {time.time()-t0:.2f}s")
    print("   generated (tail):", res.text[-200:])


if __name__ == "__main__":
    main()
