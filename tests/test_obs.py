"""Observability subsystem tests: recorder/metrics/timeline units, and
the engine-integration contract — tracing is *passive* (temp-0 output
bit-identical on/off, on every scheduling path and both attention
backends), event counts on the deterministic step clock are exactly
reproducible, traces round-trip through the JSONL schema and export to
Chrome trace-event form with genuinely overlapping DAG streams, and the
disabled recorder's overhead is a bounded attribute check."""

import json
import math
import subprocess
import sys
import time

import jax
import pytest

from repro.configs import get_config
from repro.data.tokenizer import Tokenizer
from repro.engine import EngineConfig, MedVerseEngine
from repro.models import init_params
from repro.obs import (NULL_RECORDER, MetricsRegistry, TraceRecorder,
                       load_jsonl, percentile_summary, request_timelines,
                       summarize, to_chrome, validate_spans)
from repro.serving import ContinuousScheduler, ServeRequest
from repro.serving.metrics import RequestMetrics

CFG = get_config("medverse-7b", smoke=True)

DIAMOND = ("<Plan> "
           "<Outline> Transient Step 1: q -> A ; Dependency: [] </Outline> "
           "<Outline> Transient Step 2: q -> B ; Dependency: [] </Outline> "
           "<Outline> Transient Step 3: A , B -> C ; Dependency: [1, 2] "
           "</Outline> </Plan>")


def make_tok():
    corpus = ["alpha beta gamma delta epsilon zeta eta theta iota kappa "
              "Transient Step 1: 2: 3: 4: 5: 6: 7: 8: "
              "Dependency: [] [1] [2] [1, 2] "
              "A -> B ; C D q x y z"]
    return Tokenizer.train(corpus)


@pytest.fixture(scope="module")
def setup():
    tok = make_tok()
    params = init_params(jax.random.PRNGKey(0), CFG)
    return tok, params


def make_engine(params, tok, **kw):
    base = dict(max_slots=4, page_size=4, n_pages=512, max_chain_len=256,
                max_step_tokens=6, max_conclusion_tokens=6)
    base.update(kw)
    return MedVerseEngine(params, CFG, tok, EngineConfig(**base))


# ------------------------------------------------------ recorder units -----
def test_recorder_spans_and_validation():
    rec = TraceRecorder()
    rec.set_step(3)
    rec.begin("request", "request", rid=0)
    rec.begin("stream", "stream", rid=0, track="plan")
    rec.instant("first_token", "stream", rid=0, track="plan")
    rec.end("stream", "stream", rid=0, track="plan", n_tokens=4)
    rec.end("request", "request", rid=0)
    assert validate_spans(rec.events) == []
    assert all(ev["step"] == 3 for ev in rec.events)

    bad = TraceRecorder()
    bad.begin("stream", "stream", rid=0, track="t1")
    bad.end("stream", "stream", rid=0, track="t2")   # wrong lane
    problems = validate_spans(bad.events)
    assert len(problems) == 2      # unmatched E + never-closed B
    assert any("never closed" in p for p in problems)


def test_recorder_complete_and_counter():
    rec = TraceRecorder()
    t0 = rec.now()
    rec.complete("decode", "engine", t0, n_rows=4)
    rec.counter("kv_pages", {"used": 7, "pinned": 2})
    x, c = rec.events
    assert x["ph"] == "X" and x["dur"] >= 0 and x["args"]["n_rows"] == 4
    assert c["ph"] == "C" and c["values"] == {"used": 7, "pinned": 2}


def test_null_recorder_is_inert():
    assert NULL_RECORDER.enabled is False
    # every hook is callable and returns nothing, recording nothing
    NULL_RECORDER.set_step(5)
    NULL_RECORDER.begin("x", "y", rid=1, track="t")
    NULL_RECORDER.end("x", "y")
    NULL_RECORDER.instant("x")
    NULL_RECORDER.complete("x", "y", 0.0)
    NULL_RECORDER.counter("x", {})
    NULL_RECORDER.meta(a=1)
    assert NULL_RECORDER.now() == 0.0 and NULL_RECORDER.step == 0


def test_null_recorder_overhead_bounded():
    """The untraced hot path pays one attribute check per site: a
    million guarded no-op sites must cost well under a second (the real
    decode loop has ~10 sites per step)."""
    obs = NULL_RECORDER
    t0 = time.monotonic()
    acc = 0
    for _ in range(1_000_000):
        if obs.enabled:
            acc += 1   # never taken; arguments never constructed
    dt = time.monotonic() - t0
    assert acc == 0
    assert dt < 1.0, f"1e6 disabled hook guards took {dt:.2f}s"


def test_jsonl_round_trip(tmp_path):
    rec = TraceRecorder()
    rec.meta(n_pages=64, backend="dense")
    rec.begin("request", "request", rid=0, n_prompt=5)
    rec.set_step(2)
    rec.instant("page_alloc", "kvcache", page=3)
    rec.end("request", "request", rid=0)
    path = str(tmp_path / "trace.jsonl")
    rec.dump_jsonl(path)
    header, events = load_jsonl(path)
    assert header["meta"] == {"n_pages": 64, "backend": "dense"}
    assert events == rec.events      # exact round-trip
    with pytest.raises(ValueError):
        bad = str(tmp_path / "bad.jsonl")
        with open(bad, "w") as f:
            f.write('{"schema": "other/9"}\n')
        load_jsonl(bad)


def test_chrome_export_structure():
    rec = TraceRecorder()
    rec.begin("stream", "stream", rid=7, track="t1")
    rec.end("stream", "stream", rid=7, track="t1")
    rec.counter("kv_pages", {"used": 1})
    doc = to_chrome(rec.events, {"backend": "dense"})
    evs = doc["traceEvents"]
    assert doc["otherData"]["backend"] == "dense"
    names = [(e["ph"], e.get("name")) for e in evs]
    assert ("M", "process_name") in names     # request 7 named
    assert ("M", "thread_name") in names      # track t1 named
    assert any(e["ph"] == "B" and e["pid"] == 7 for e in evs)
    assert any(e["ph"] == "C" for e in evs)
    # wall seconds scaled to microseconds
    b = next(e for e in evs if e["ph"] == "B")
    assert b["ts"] == pytest.approx(rec.events[0]["ts"] * 1e6)


# ------------------------------------------------------- metrics units -----
def test_metrics_registry_and_prom_text():
    reg = MetricsRegistry(prefix="medverse_")
    reg.counter("steps_total", "decode steps").inc(3)
    reg.counter("steps_total").inc(2)           # get-or-create merges
    reg.gauge("pages", "occupancy").set(7)
    h = reg.histogram("chain_bucket", buckets=[64, 128], help="widths")
    h.observe(64, 5)
    h.observe(128, 2)
    h.observe(999)                              # lands in +Inf
    snap = reg.snapshot()
    assert snap["medverse_steps_total"] == 5
    assert snap["medverse_pages"] == 7
    assert snap["medverse_chain_bucket"]["count"] == 8
    text = reg.to_prom_text()
    assert "# TYPE medverse_steps_total counter" in text
    assert "medverse_steps_total 5" in text
    assert 'medverse_chain_bucket_bucket{le="64"} 5' in text
    assert 'medverse_chain_bucket_bucket{le="128"} 7' in text   # cumulative
    assert 'medverse_chain_bucket_bucket{le="+Inf"} 8' in text
    with pytest.raises(AssertionError):
        reg.gauge("steps_total")                # type mismatch
    with pytest.raises(AssertionError):
        reg.counter("steps_total").inc(-1)      # counters never decrease


def test_percentile_summary():
    out = percentile_summary(list(range(1, 101)))
    assert out["p50"] == pytest.approx(50.5)
    assert out["p95"] == pytest.approx(95.05)
    assert out["p99"] == pytest.approx(99.01)
    assert percentile_summary([]) is None


def test_request_metrics_tpot_steps():
    m = RequestMetrics(first_token_step=10, done_step=30, n_tokens=11)
    assert m.tpot_steps == pytest.approx(2.0)
    assert math.isnan(RequestMetrics(n_tokens=1).tpot_steps)
    assert math.isnan(RequestMetrics(n_tokens=5).tpot_steps)  # no steps yet


# ------------------------------------------------------- timeline units ----
def _stream_span(rid, track, b_step, e_step, purpose="step", tid=0,
                 n_tokens=3):
    return [
        {"ph": "B", "name": "stream", "cat": "stream", "ts": float(b_step),
         "step": b_step, "rid": rid, "track": track,
         "args": {"purpose": purpose, "tid": tid}},
        {"ph": "E", "name": "stream", "cat": "stream", "ts": float(e_step),
         "step": e_step, "rid": rid, "track": track,
         "args": {"n_tokens": n_tokens}},
    ]


def test_timeline_critical_path_and_overlap():
    events = (_stream_span(0, "plan", 0, 10, purpose="plan", tid=-1)
              + _stream_span(0, "t1", 10, 20, tid=0)
              + _stream_span(0, "t2", 10, 24, tid=1)
              + _stream_span(0, "conclusion", 24, 30,
                             purpose="conclusion", tid=-1))
    tls = request_timelines(events)
    tl = tls[0]
    assert len(tl.streams) == 4
    assert tl.critical_path_steps == 30
    assert tl.sum_chain_steps == 10 + 10 + 14 + 6
    assert tl.max_overlap == 2               # t1 and t2 concurrently
    assert tl.parallelism == pytest.approx(40 / 30)
    # a stream ending exactly where the next spawns does not overlap
    serial = request_timelines(_stream_span(1, "t1", 0, 5)
                               + _stream_span(1, "t2", 5, 9))
    assert serial[1].max_overlap == 1
    assert "max_overlap=2" in summarize(events)


def test_timeline_drops_aborted_streams():
    events = _stream_span(0, "t1", 0, 8)
    aborted = _stream_span(0, "t2", 0, 4)
    aborted[1]["args"]["aborted"] = True
    tls = request_timelines(events + aborted)
    assert [s.track for s in tls[0].streams] == ["t1"]


# -------------------------------------------------- engine integration -----
def _event_signature(eng):
    """(ph, name, step) multiset — the deterministic view of a trace."""
    return sorted((ev["ph"], ev["name"], ev["step"])
                  for ev in eng.obs.events)


def test_traced_runs_are_deterministic(setup):
    """Two traced runs of the same workload produce identical event
    signatures on the step clock (wall timestamps differ, counts and
    steps never)."""
    tok, params = setup
    prompts = ["q alpha beta", "q beta gamma"]
    sigs = []
    for _ in range(2):
        eng = make_engine(params, tok, plan_override=DIAMOND, trace=True)
        eng.generate(prompts)
        assert validate_spans(eng.obs.events) == []
        sigs.append(_event_signature(eng))
    assert sigs[0] == sigs[1]


PARITY_CASES = [
    ("dense", {}),
    ("dense", {"async_frontier": True}),
    ("dense", {"speculative": True}),
    ("dense", {"n_pages": 40}),             # 40 pages forces preemption
    ("pallas", {}),
]


@pytest.mark.parametrize(
    "backend,variant", PARITY_CASES,
    ids=["dense", "async", "spec", "preempt", "pallas"])
def test_temp0_parity_tracing_on_off(setup, backend, variant):
    """Tracing is passive on every scheduling path (sync, async,
    speculative, preemption) under both attention backends: temp-0
    output text and decode-iteration counts are bit-identical with
    tracing on or off."""
    tok, params = setup
    kw = dict(plan_override=DIAMOND, attention_backend=backend,
              kernel_interpret=True, **variant)
    prompts = ["q alpha beta", "q beta gamma"]
    off = make_engine(params, tok, **kw)
    r_off = off.generate(prompts)
    on = make_engine(params, tok, trace=True, **kw)
    r_on = on.generate(prompts)
    assert [r.text for r in r_on] == [r.text for r in r_off]
    assert [r.step_texts for r in r_on] == [r.step_texts for r in r_off]
    assert on.total_iters == off.total_iters
    assert len(on.obs.events) > 0          # ...while actually recording
    if variant.get("n_pages") == 40:
        assert on.preemptions > 0          # the path actually exercised


def test_untraced_engine_uses_null_recorder(setup):
    tok, params = setup
    eng = make_engine(params, tok, plan_override=DIAMOND)
    assert eng.obs is NULL_RECORDER
    assert eng.alloc.tracer is NULL_RECORDER
    assert eng.radix.tracer is NULL_RECORDER
    with pytest.raises(ValueError):
        eng.dump_trace()                    # tracing is off


def test_engine_trace_schema_and_chrome_overlap(setup, tmp_path):
    """A traced diamond run dumps a valid JSONL trace (schema-checked by
    tools/check_trace.py, stdlib-only) plus a Chrome export in which at
    least two DAG-transition streams of one request overlap in time —
    the parallel-frontier acceptance bar."""
    tok, params = setup
    path = str(tmp_path / "trace.jsonl")
    eng = make_engine(params, tok, plan_override=DIAMOND, trace=path)
    eng.generate(["q alpha beta"])
    jsonl_path, chrome_path = eng.dump_trace()
    assert jsonl_path == path
    header, events = load_jsonl(path)
    assert header["meta"]["n_pages"] == 512
    assert events == eng.obs.events
    # external validator: spans closed, ids resolve, chrome well-formed
    proc = subprocess.run(
        [sys.executable, "tools/check_trace.py", path],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    # the diamond's two middle transitions genuinely ran in parallel
    tls = request_timelines(events)
    assert max(tl.max_overlap for tl in tls.values()) >= 2
    with open(chrome_path) as f:
        chrome = json.load(f)
    t1 = [e for e in chrome["traceEvents"]
          if e.get("name") == "stream" and e["ph"] in ("B", "E")]
    assert len(t1) >= 10                    # 5 streams, B+E each


def test_scheduler_trace_and_report_merge(setup):
    """The serving scheduler emits arrival/admit/queue-depth through the
    engine's recorder, and its report merges the engine metrics
    snapshot plus the tpot_steps percentile block."""
    tok, params = setup
    eng = make_engine(params, tok, trace=True)
    sched = ContinuousScheduler(eng, clock="step")
    wl = [ServeRequest(prompt="q alpha", plan=DIAMOND, arrival=0.0),
          ServeRequest(prompt="q beta", plan=DIAMOND, arrival=3.0)]
    rep = sched.run(wl)
    assert rep.n_completed == 2
    names = {ev["name"] for ev in eng.obs.events}
    assert {"arrival", "admit", "queue_depth"} <= names
    assert validate_spans(eng.obs.events) == []
    # p99 everywhere, plus the deterministic TPOT block
    for block in (rep.ttft_s, rep.ttft_steps, rep.tpot_s, rep.e2e_s,
                  rep.tpot_steps):
        assert set(block) == {"mean", "p50", "p95", "p99"}
    assert rep.tpot_steps["mean"] > 0
    # engine registry snapshot rides along in the report dict
    assert rep.engine is not None
    assert rep.engine["medverse_decode_steps_total"] == eng.total_iters
    assert rep.engine["medverse_kv_pages_total"] == 512
    # the bucket histograms and padding-waste ratio ship with it
    assert rep.engine["medverse_decode_chain_bucket"]["count"] == sum(
        eng.bucket_hist.values())
    assert "medverse_decode_page_bucket" in rep.engine
    assert 0.0 <= rep.engine["medverse_padding_waste_ratio"] < 1.0
    assert "engine" in rep.to_dict()


def test_trace_abort_midflight_balanced(setup, tmp_path):
    """Aborting a request mid-flight must leave the trace structurally
    clean: every opened span closed, the external validator green, the
    Chrome export balanced, and the aborted request's end event still
    carrying its cost summary."""
    tok, params = setup
    path = str(tmp_path / "abort.jsonl")
    eng = make_engine(params, tok, plan_override=DIAMOND, trace=path)
    rid = eng.add_request("q alpha beta")
    for _ in range(8):
        eng.step()
    assert eng.n_requests() == 1           # genuinely mid-flight
    assert eng.abort(rid)
    assert validate_spans(eng.obs.events) == []
    ends = [ev for ev in eng.obs.events
            if ev["ph"] == "E" and ev["name"] == "request"]
    assert len(ends) == 1 and ends[0]["args"]["reason"] == "aborted"
    assert ends[0]["args"]["cost"]["decode"]["rows"] > 0
    jsonl_path, chrome_path = eng.dump_trace()
    proc = subprocess.run(
        [sys.executable, "tools/check_trace.py", jsonl_path],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    with open(chrome_path) as f:
        chrome = json.load(f)
    assert chrome["traceEvents"]


def test_trace_preemption_balanced(setup, tmp_path):
    """Preemption (page-pool pressure evicts and restarts a request)
    must also keep spans balanced and the trace file valid."""
    tok, params = setup
    path = str(tmp_path / "preempt.jsonl")
    eng = make_engine(params, tok, plan_override=DIAMOND, trace=path,
                      n_pages=40)
    eng.generate(["q alpha beta", "q beta gamma"])
    assert eng.preemptions > 0             # the path actually exercised
    assert validate_spans(eng.obs.events) == []
    jsonl_path, _ = eng.dump_trace()
    proc = subprocess.run(
        [sys.executable, "tools/check_trace.py", jsonl_path],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_metrics_registry_matches_engine_counters(setup):
    tok, params = setup
    eng = make_engine(params, tok, plan_override=DIAMOND)
    eng.generate(["q alpha beta", "q alpha beta"])
    snap = eng.metrics_registry().snapshot()
    s = eng.alloc.stats()
    assert snap["medverse_kv_pages_allocated_total"] == s["allocs"]
    assert snap["medverse_kv_pages_freed_total"] == s["frees"]
    assert snap["medverse_kv_pages_peak_in_use"] == s["peak_in_use"]
    assert snap["medverse_radix_hits_total"] == eng.radix.hits
    assert snap["medverse_radix_inserts_total"] == eng.radix.inserts
    assert snap["medverse_decode_steps_total"] == eng.total_iters
    assert snap["medverse_decode_chain_bucket"]["count"] == sum(
        eng.bucket_hist.values())
    text = eng.metrics_registry().to_prom_text()
    assert "# TYPE medverse_radix_hits_total counter" in text
