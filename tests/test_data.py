"""Curator / tokenizer / dataset tests (incl. properties)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.plan import parse_plan
from repro.core.topology import PAD_SEG
from repro.data import (
    Corpus,
    Curator,
    Tokenizer,
    build_kg,
    encode_example,
    generate_qa,
    make_batches,
    pad_example,
)
from repro.data.tokenizer import BOS, EOS, PAD, SPECIALS


# ------------------------------------------------------------- tokenizer ---
def test_tokenizer_roundtrip_words():
    tok = Tokenizer.train(["alpha beta gamma <Plan> delta </Plan>"])
    ids = tok.encode("alpha <Plan> beta </Plan>")
    assert tok.decode(ids) == "alpha <Plan> beta </Plan>"


def test_tokenizer_specials_single_tokens():
    tok = Tokenizer.train(["x"])
    for s in SPECIALS[4:]:
        ids = tok.encode(s)
        assert len(ids) == 1, s
        assert tok.inv[ids[0]] == s


def test_tokenizer_unk():
    tok = Tokenizer.train(["known words"])
    ids = tok.encode("unknown stuff known")
    assert ids[0] == 1 and ids[1] == 1  # <unk>
    assert tok.decode([ids[2]]) == "known"


@settings(max_examples=30, deadline=None)
@given(st.lists(st.sampled_from("abcde fgh ij klm nop".split()),
                min_size=1, max_size=20))
def test_property_tokenizer_roundtrip(words):
    tok = Tokenizer.train(["abcde fgh ij klm nop"])
    text = " ".join(words)
    assert tok.decode(tok.encode(text)) == text


# ---------------------------------------------------------------- curator --
@pytest.fixture(scope="module")
def kg_items():
    kg = build_kg(20, seed=3)
    items = generate_qa(kg, 64, seed=4)
    return kg, items


def test_curator_produces_valid_examples(kg_items):
    kg, items = kg_items
    cur = Curator(kg)
    exs = cur.curate_all(items)
    assert len(exs) > len(items) // 2, cur.stats
    for ex in exs[:10]:
        # plan reparses to the same DAG (the dual-layer syntax check,
        # re-verified independently here)
        plan2 = parse_plan(ex.prefix_text)
        assert plan2.to_dag().deps == ex.dag.deps
        # answer is stated in the conclusion
        assert ex.answer_text in ex.conclusion_text


def test_curator_kg_grounding(kg_items):
    """Every reasoning edge in every curated plan exists in the KG —
    the paper's knowledge-grounding guarantee."""
    kg, items = kg_items
    cur = Curator(kg)
    for ex in cur.curate_all(items)[:20]:
        for step in ex.plan.steps:
            lhs, tgt = step.label.rsplit("->", 1)
            for src in (s.strip() for s in lhs.split(",")):
                assert kg.has_edge(src, tgt.strip()), (src, tgt)


def test_curator_stats_track_failures(kg_items):
    kg, items = kg_items
    cur = Curator(kg)
    cur.curate_all(items)
    assert cur.stats.n_ok > 0
    assert cur.stats.n_items == len(items)


# ---------------------------------------------------------------- dataset --
@pytest.fixture(scope="module")
def corpus():
    return Corpus.build(n_items=80, n_clusters=16, seed=7)


def test_encode_targets_segment_local(corpus):
    ex = next(e for e in corpus.train if len(e.step_texts) >= 2)
    enc = encode_example(ex, corpus.tokenizer)
    # boundaries: where seg changes, prediction is masked
    s = enc.length
    for i in range(s - 1):
        if enc.seg_id[i] != enc.seg_id[i + 1]:
            assert enc.loss_mask[i] == 0.0
        if enc.loss_mask[i] > 0:
            assert enc.targets[i] == enc.tokens[i + 1]
    # question/options are never supervised
    assert enc.loss_mask[:5].sum() == 0


def test_encode_causal_variant(corpus):
    ex = corpus.train[0]
    enc = encode_example(ex, corpus.tokenizer, causal=True)
    assert (enc.seg_id == 0).all()
    assert (enc.pos_id == np.arange(enc.length)).all()
    enc_d = encode_example(ex, corpus.tokenizer, causal=False)
    # same tokens either way — only the metadata differs
    assert np.array_equal(enc.tokens, enc_d.tokens)


def test_pad_and_batch(corpus):
    encs = [encode_example(e, corpus.tokenizer) for e in corpus.train[:9]]
    batches = make_batches(encs, 4, 384)
    assert batches, "no batches produced"
    b = batches[0]
    assert b["tokens"].shape == (4, 384)
    pad_region = b["seg_id"] == PAD_SEG
    assert (b["loss_mask"][pad_region] == 0).all()


def test_adaptive_positions_parallel_steps(corpus):
    """Sibling steps in the same frontier share their starting pos_id."""
    ex = next(e for e in corpus.train
              if e.topology == "complex_intersecting")
    enc = encode_example(ex, corpus.tokenizer)
    layers = ex.dag.topological_layers()
    wide = next((l for l in layers if len(l) >= 2), None)
    if wide is None:
        pytest.skip("no wide frontier in this example")
    starts = []
    for t in wide:
        idx = np.where(enc.seg_id == t + 1)[0]
        starts.append(enc.pos_id[idx[0]])
    assert len(set(starts)) == 1
