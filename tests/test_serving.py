"""Continuous-batching serving subsystem tests: step-level API parity
with generate(), staggered-arrival TTFT vs the closed-batch baseline,
scheduling policies, streaming callbacks, abort, and the KV-pressure
paths (preemption + re-admission without leaks; radix eviction before
preemption)."""

import jax
import pytest

from repro.configs import get_config
from repro.data.tokenizer import Tokenizer
from repro.engine import EngineConfig, MedVerseEngine, OutOfPagesError
from repro.models import init_params
from repro.serving import (ChainAwarePolicy, ContinuousScheduler, FCFSPolicy,
                           RequestQueue, ServeRequest,
                           estimate_frontier_width, make_policy)

CFG = get_config("medverse-7b", smoke=True)

DIAMOND = ("<Plan> "
           "<Outline> Transient Step 1: q -> A ; Dependency: [] </Outline> "
           "<Outline> Transient Step 2: q -> B ; Dependency: [] </Outline> "
           "<Outline> Transient Step 3: A , B -> C ; Dependency: [1, 2] "
           "</Outline> </Plan>")

FANOUT = ("<Plan> "
          "<Outline> Transient Step 1: alpha ; Dependency: [] </Outline> "
          "<Outline> Transient Step 2: beta ; Dependency: [] </Outline> "
          "<Outline> Transient Step 3: gamma ; Dependency: [] </Outline> "
          "</Plan>")

SERIAL = ("<Plan> "
          "<Outline> Transient Step 1: alpha ; Dependency: [] </Outline> "
          "</Plan>")


def make_tok():
    corpus = ["alpha beta gamma delta epsilon zeta eta theta iota kappa "
              "Transient Step 1: 2: 3: 4: 5: 6: 7: 8: "
              "Dependency: [] [1] [2] [1, 2] "
              "A -> B ; C D q x y z"]
    return Tokenizer.train(corpus)


@pytest.fixture(scope="module")
def setup():
    tok = make_tok()
    params = init_params(jax.random.PRNGKey(0), CFG)
    return tok, params


def make_engine(params, tok, **kw):
    base = dict(max_slots=4, page_size=4, n_pages=512, max_chain_len=256,
                max_step_tokens=6, max_conclusion_tokens=6)
    base.update(kw)
    return MedVerseEngine(params, CFG, tok, EngineConfig(**base))


def drain(eng):
    """Step the engine until idle; {rid: GenResult}."""
    results = {}
    while eng.n_requests():
        for ev in eng.step():
            if ev.kind == "done":
                results[ev.rid] = ev.result
    return results


def assert_pool_invariants(alloc):
    """PageAllocator.stats() lifetime-counter invariants that hold at
    any quiescent point (see its docstring): alloc/free balance
    explains occupancy, pin/unpin balance explains outstanding pins,
    and the high-water mark stayed inside the pool."""
    s = alloc.stats()
    assert s["allocs"] - s["frees"] == s["in_use"], s
    assert s["pins"] - s["unpins"] == sum(alloc.pinned.values()), s
    assert s["in_use"] >= s["used"], s
    assert 0 <= s["peak_in_use"] <= s["n_pages"], s
    assert s["peak_in_use"] >= s["in_use"], s


# --------------------------------------------------- step-level API --------
def test_step_api_matches_generate(setup):
    """generate() is a thin wrapper over add_request/step: a manual
    step-driven loop produces bit-identical temp-0 output."""
    tok, params = setup
    prompts = ["q alpha beta", "q beta gamma", "q gamma delta"]
    e1 = make_engine(params, tok, plan_override=DIAMOND)
    ref = e1.generate(prompts)
    e2 = make_engine(params, tok, plan_override=DIAMOND)
    rids = [e2.add_request(p) for p in prompts]
    results = drain(e2)
    assert [results[r].text for r in rids] == [r.text for r in ref]
    assert [results[r].step_texts for r in rids] == [
        r.step_texts for r in ref]


def test_has_capacity_and_free_slots(setup):
    tok, params = setup
    eng = make_engine(params, tok, plan_override=DIAMOND, max_slots=2)
    assert eng.has_capacity() and eng.n_free_slots() == 2
    eng.add_request("q alpha")
    assert eng.has_capacity() and eng.n_free_slots() == 1
    eng.add_request("q beta")
    assert not eng.has_capacity()
    drain(eng)
    assert eng.has_capacity() and eng.n_requests() == 0


def test_abort_releases_pages(setup):
    tok, params = setup
    eng = make_engine(params, tok, plan_override=DIAMOND,
                      radix_cache=False)
    used0 = eng.alloc.used
    rid = eng.add_request("q alpha beta")
    for _ in range(8):
        eng.step()
    assert eng.alloc.used > used0
    assert eng.abort(rid)
    assert not eng.abort(rid)          # already gone
    assert eng.alloc.used == used0
    assert eng.n_requests() == 0 and eng.step() == []


def test_step_events_stream_tokens(setup):
    """Every decoded token surfaces as a token event; done carries the
    result whose n_tokens equals the token-event count."""
    tok, params = setup
    eng = make_engine(params, tok, plan_override=DIAMOND)
    rid = eng.add_request("q alpha beta")
    n_tok, result = 0, None
    while eng.n_requests():
        for ev in eng.step():
            if ev.kind == "token":
                assert ev.rid == rid and ev.token >= 0
                n_tok += 1
            elif ev.kind == "done":
                result = ev.result
    assert result is not None and result.ok
    assert n_tok == result.n_tokens


# ------------------------------------------------ continuous batching ------
def _staggered_workload():
    return [ServeRequest(prompt="q alpha beta", plan=DIAMOND, arrival=0.0),
            ServeRequest(prompt="q beta gamma", plan=DIAMOND, arrival=0.0),
            ServeRequest(prompt="q gamma delta", plan=DIAMOND, arrival=6.0),
            ServeRequest(prompt="q delta epsilon", plan=DIAMOND,
                         arrival=6.0)]


def test_continuous_beats_closed_batch_on_ttft(setup):
    """Late arrivals are admitted mid-flight instead of waiting for the
    batch to drain: strictly better mean TTFT (and no worse in steps
    overall), measured on the deterministic step clock."""
    tok, params = setup
    reports = {}
    for closed in (False, True):
        eng = make_engine(params, tok)
        sched = ContinuousScheduler(eng, policy="fcfs", clock="step",
                                    closed_batch=closed)
        reports[closed] = sched.run(_staggered_workload())
    cont, closed = reports[False], reports[True]
    assert cont.n_completed == closed.n_completed == 4
    assert cont.ttft_steps["mean"] < closed.ttft_steps["mean"]
    assert cont.n_steps <= closed.n_steps


def test_serving_metrics_populated(setup):
    tok, params = setup
    eng = make_engine(params, tok)
    sched = ContinuousScheduler(eng, policy="fcfs", clock="step",
                                deadline_s=60.0)
    rep = sched.run(_staggered_workload())
    assert rep.n_requests == rep.n_completed == 4
    assert rep.total_tokens > 0 and rep.throughput_tok_s > 0
    assert 0.0 <= rep.goodput <= 1.0
    for req in sched.finished:
        m = req.metrics
        assert m.ttft_steps >= 0
        assert m.done_step >= m.first_token_step >= m.arrival_step >= 0
        assert m.n_tokens == req.result.n_tokens
    d = rep.to_dict()
    assert d["policy"] == "fcfs" and d["ttft_steps"]["mean"] >= 0


def test_streaming_callback_receives_every_token(setup):
    tok, params = setup
    eng = make_engine(params, tok)
    got = []
    req = ServeRequest(prompt="q alpha beta", plan=DIAMOND, arrival=0.0,
                       on_token=lambda rid, t, text: got.append((rid, t, text)))
    sched = ContinuousScheduler(eng, clock="step")
    sched.run([req])
    assert req.result is not None
    assert len(got) == req.result.n_tokens
    assert all(r == req.rid for r, _, _ in got)
    # the streamed pieces decode to real vocabulary
    assert all(isinstance(text, str) for _, _, text in got)


# ------------------------------------------------------------ policies -----
def test_estimate_frontier_width():
    assert estimate_frontier_width(DIAMOND) == 2
    assert estimate_frontier_width(FANOUT) == 3
    assert estimate_frontier_width(SERIAL) == 1
    assert estimate_frontier_width(None) == 1
    assert estimate_frontier_width("not a plan") == 1


def test_chain_aware_policy_fills_idle_slots():
    waiting = [ServeRequest(prompt="a", plan=SERIAL),
               ServeRequest(prompt="b", plan=FANOUT),
               ServeRequest(prompt="c", plan=DIAMOND)]
    pol = ChainAwarePolicy()
    assert pol.select(waiting, free_slots=4) == 1   # fan-out (width 3)
    assert pol.select(waiting, free_slots=2) == 2   # diamond (width 2)
    assert pol.select(waiting, free_slots=1) == 0   # serial fits exactly
    assert FCFSPolicy().select(waiting, free_slots=4) == 0
    assert make_policy("chain-aware").name == "chain-aware"
    with pytest.raises(ValueError):
        make_policy("nope")


def test_queue_preempted_priority_lane():
    q = RequestQueue("fcfs")
    a, b, c = (ServeRequest(prompt=p) for p in "abc")
    q.push(a)
    q.push(b)
    q.requeue(c)             # preemption victim jumps the line
    assert len(q) == 3
    assert q.pop(1) is c
    assert q.pop(1) is a
    assert q.pop(1) is b
    q.push(a)
    q.push_front(b)          # failed admission keeps its spot at the head
    assert q.pop(1) is b
    assert q.pop(1) is a
    assert q.pop(1) is None


def test_chain_aware_policy_in_scheduler(setup):
    """End-to-end chain-aware run completes everything and reports its
    policy name."""
    tok, params = setup
    eng = make_engine(params, tok)
    wl = [ServeRequest(prompt="q alpha", plan=FANOUT, arrival=0.0),
          ServeRequest(prompt="q beta", plan=SERIAL, arrival=0.0),
          ServeRequest(prompt="q gamma", plan=DIAMOND, arrival=2.0)]
    rep = ContinuousScheduler(eng, policy="chain-aware",
                              clock="step").run(wl)
    assert rep.policy == "chain-aware" and rep.n_completed == 3


# ------------------------------------------------------- KV pressure -------
def test_preemption_recovers_without_leaks(setup):
    """A deliberately undersized pool forces preemption mid-decode; the
    victim is re-admitted and every request completes, with zero leaked
    pages afterwards (alloc.used back to zero)."""
    tok, params = setup
    eng = make_engine(params, tok, n_pages=40)
    sched = ContinuousScheduler(eng, clock="step")
    wl = [ServeRequest(prompt="q alpha beta", plan=DIAMOND, arrival=0.0),
          ServeRequest(prompt="q beta gamma", plan=DIAMOND, arrival=0.0)]
    rep = sched.run(wl, max_steps=5000)
    assert rep.n_completed == 2
    assert eng.preemptions > 0 and rep.n_preemptions > 0
    assert eng.alloc.used == 0                       # no leaked pages
    # every page still resident is explained by a radix cache pin
    assert eng.alloc.pages_in_use == eng.alloc.pinned_pages
    assert_pool_invariants(eng.alloc)
    # preemption forced real page churn: frees happened, and the pool
    # high-water mark proves the pressure was genuine
    s = eng.alloc.stats()
    assert s["frees"] > 0 and s["peak_in_use"] >= s["in_use"]
    # the preempted request kept its rid and finished
    assert all(r.state == "done" for r in sched.finished)


def test_generate_survives_preemption(setup):
    """The closed-batch wrapper re-queues preemption victims itself:
    generate() under a tiny pool completes instead of crashing."""
    tok, params = setup
    eng = make_engine(params, tok, n_pages=40, plan_override=DIAMOND)
    res = eng.generate(["q alpha beta", "q beta gamma"])
    assert len(res) == 2 and all(r.ok for r in res)
    assert eng.preemptions > 0
    assert eng.alloc.used == 0
    assert_pool_invariants(eng.alloc)


def test_radix_pins_evicted_before_preemption(setup):
    """Pinned-only radix pages are reclaimable cache: under pressure the
    allocator evicts them (LRU) before any live request is preempted."""
    tok, params = setup
    eng = make_engine(params, tok, n_pages=60, plan_override=DIAMOND)
    # warm the radix cache with distinct long prompts -> pinned pages
    long_prompts = [
        " ".join(["q"] + [w] * 24) for w in
        ("alpha", "beta", "gamma")]
    for p in long_prompts:
        eng.generate([p])
    assert eng.alloc.pinned_pages >= 12
    assert eng.alloc.used == 0
    # two fresh concurrent requests need more pages than remain free;
    # evicting cache pins covers it, so nobody gets preempted
    sched = ContinuousScheduler(eng, clock="step")
    wl = [ServeRequest(prompt="q delta epsilon", plan=DIAMOND, arrival=0.0),
          ServeRequest(prompt="q epsilon zeta", plan=DIAMOND, arrival=0.0)]
    rep = sched.run(wl, max_steps=5000)
    assert rep.n_completed == 2
    assert eng.radix.evictions > 0
    assert eng.preemptions == 0
    assert eng.alloc.used == 0
    assert_pool_invariants(eng.alloc)
    # radix evictions show up as unpins in the allocator's lifetime
    # counters — the eviction path is fully accounted
    assert eng.alloc.stats()["unpins"] > 0


def test_scheduler_fails_oversized_request_keeps_serving(setup):
    """A request whose working set can never fit the pool is failed in
    place (aborted, state='failed') — the rest of the fleet keeps
    serving and the run still produces a report."""
    tok, params = setup
    wide8 = ("<Plan> " + " ".join(
        f"<Outline> Transient Step {i}: alpha beta gamma ; "
        "Dependency: [] </Outline>" for i in range(1, 9)) + " </Plan>")
    eng = make_engine(params, tok, n_pages=40)
    sched = ContinuousScheduler(eng, clock="step")
    wl = [ServeRequest(prompt="q alpha beta", plan=DIAMOND, arrival=0.0),
          ServeRequest(prompt="q beta gamma", plan=wide8, arrival=0.0)]
    rep = sched.run(wl, max_steps=5000)
    assert sorted(r.state for r in sched.finished) == ["done", "failed"]
    assert rep.n_requests == 2 and rep.n_completed == 1
    assert eng.n_requests() == 0
    assert eng.alloc.used == 0        # the abort released every page


def test_single_oversized_request_raises(setup):
    """With nothing to preempt (a lone request that cannot fit), the
    engine surfaces OutOfPagesError rather than thrashing."""
    tok, params = setup
    eng = make_engine(params, tok, n_pages=8, radix_cache=False,
                      plan_override=DIAMOND)
    with pytest.raises(OutOfPagesError):
        eng.generate(["q alpha beta"])
