"""Scheduler-path tests: async-frontier vs synchronized equivalence,
chain bucketing, explicit page reclamation, ordered-dedup join
refcounts, and cross-request radix prefix reuse."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokenizer import Tokenizer
from repro.engine import (
    EngineConfig,
    IndexChain,
    MedVerseEngine,
    PageAllocator,
    PoolConfig,
    SerialEngine,
)
from repro.models import init_params

CFG = get_config("medverse-7b", smoke=True)

DIAMOND = ("<Think> 1. q -> A -> C. 2. q -> B -> C. </Think> <Plan> "
           "<Outline> Transient Step 1: q -> A ; Dependency: [] </Outline> "
           "<Outline> Transient Step 2: q -> B ; Dependency: [] </Outline> "
           "<Outline> Transient Step 3: A , B -> C ; Dependency: [1, 2] "
           "</Outline> </Plan>")

FANOUT = ("<Plan> "
          "<Outline> Transient Step 1: alpha ; Dependency: [] </Outline> "
          "<Outline> Transient Step 2: beta ; Dependency: [] </Outline> "
          "<Outline> Transient Step 3: gamma ; Dependency: [] </Outline> "
          "</Plan>")

# one long independent branch (verbose label => long forced header) plus
# a two-step chain: the synchronized path gates step 2 on step 3
_LONG = " ".join(["gamma delta epsilon zeta eta theta iota kappa"] * 3)
MIXED_DEPTH = (
    "<Plan> "
    "<Outline> Transient Step 1: alpha ; Dependency: [] </Outline> "
    "<Outline> Transient Step 2: beta ; Dependency: [1] </Outline> "
    f"<Outline> Transient Step 3: {_LONG} ; Dependency: [] </Outline> "
    "</Plan>")


def make_tok():
    corpus = ["alpha beta gamma delta epsilon zeta eta theta iota kappa "
              "Transient Step 1: 2: 3: Dependency: [] [1] [2] [1, 2] "
              "A -> B ; C D q x y z"]
    return Tokenizer.train(corpus)


@pytest.fixture(scope="module")
def setup():
    tok = make_tok()
    params = init_params(jax.random.PRNGKey(0), CFG)
    return tok, params


def make_engine(params, tok, **kw):
    base = dict(max_slots=4, page_size=4, n_pages=512, max_chain_len=256,
                max_step_tokens=6, max_conclusion_tokens=6)
    base.update(kw)
    return MedVerseEngine(params, CFG, tok, EngineConfig(**base))


@pytest.mark.parametrize("plan", [DIAMOND, FANOUT], ids=["diamond", "fanout"])
def test_async_matches_sync_text(setup, plan):
    """Temperature-0 output is identical across scheduler modes on DAGs
    where every join covers its frontier (the per-transition join-max
    equals the global frontier max)."""
    tok, params = setup
    e_sync = make_engine(params, tok, plan_override=plan)
    e_async = make_engine(params, tok, plan_override=plan,
                          async_frontier=True)
    rs = e_sync.generate(["q alpha beta"])[0]
    ra = e_async.generate(["q alpha beta"])[0]
    assert rs.text == ra.text
    assert rs.step_texts == ra.step_texts
    assert rs.conclusion == ra.conclusion
    assert e_sync.last_iters == e_async.last_iters


def test_async_fewer_iters_on_mixed_depth(setup):
    """With one long independent branch, the synchronized path stalls the
    short chain's successor at the frontier barrier; the async path
    overlaps it and finishes in strictly fewer decode iterations."""
    tok, params = setup
    e_sync = make_engine(params, tok, plan_override=MIXED_DEPTH,
                         max_step_tokens=4, max_conclusion_tokens=4)
    e_async = make_engine(params, tok, plan_override=MIXED_DEPTH,
                          max_step_tokens=4, max_conclusion_tokens=4,
                          async_frontier=True)
    rs = e_sync.generate(["q alpha"])[0]
    ra = e_async.generate(["q alpha"])[0]
    assert rs.ok and ra.ok
    assert len(rs.step_texts) == len(ra.step_texts) == 3
    assert e_async.last_iters < e_sync.last_iters


@pytest.mark.parametrize("async_frontier", [False, True])
def test_pages_reclaimed_after_generate(setup, async_frontier):
    """alloc.used returns to its pre-request level after every generate()
    — request chains are released; only radix cache pins persist, and
    those are excluded from ``used`` (and fully accounted)."""
    tok, params = setup
    eng = make_engine(params, tok, plan_override=DIAMOND,
                      async_frontier=async_frontier)
    used_before = eng.alloc.used
    eng.generate(["q alpha beta"])
    assert eng.alloc.used == used_before
    # every in-use page is explained by a radix pin
    assert eng.alloc.pages_in_use == eng.alloc.used + eng.alloc.pinned_pages
    # and again, on a second call (warm radix)
    eng.generate(["q alpha beta"])
    assert eng.alloc.used == used_before
    # stats() lifetime counters agree with occupancy: alloc/free balance
    # explains in-use pages, pin/unpin balance explains outstanding pins,
    # and the high-water mark stayed inside the pool
    s = eng.alloc.stats()
    assert s["allocs"] - s["frees"] == s["in_use"]
    assert s["pins"] - s["unpins"] == sum(eng.alloc.pinned.values())
    assert s["in_use"] <= s["peak_in_use"] <= s["n_pages"]


def test_serial_engine_reclaims_pages(setup):
    tok, params = setup
    eng = SerialEngine(params, CFG, tok,
                       EngineConfig(max_slots=2, page_size=4, n_pages=256,
                                    max_chain_len=128))
    used_before = eng.inner.alloc.used
    eng.generate(["alpha beta"], max_tokens=8)
    assert eng.inner.alloc.used == used_before


def test_radix_hit_allocates_fewer_pages(setup):
    """A repeated prompt adopts cached prefix slots instead of
    re-allocating prompt pages (cross-request reuse)."""
    tok, params = setup
    eng = make_engine(params, tok, plan_override=DIAMOND)
    prompt = "q alpha beta gamma delta epsilon zeta eta theta iota kappa"
    eng.generate([prompt])
    cold = eng.alloc.total_allocated
    eng.generate([prompt])
    warm = eng.alloc.total_allocated - cold
    assert eng.radix.hits >= 1
    assert warm < cold
    # and the cached prefix produces the same K/V context: text matches
    cold_eng = make_engine(params, tok, plan_override=DIAMOND,
                           radix_cache=False)
    r_cold = cold_eng.generate([prompt])[0]
    r_warm = eng.generate([prompt])[0]
    assert r_cold.text == r_warm.text


def test_radix_disabled_no_pins(setup):
    tok, params = setup
    eng = make_engine(params, tok, plan_override=DIAMOND, radix_cache=False)
    eng.generate(["q alpha beta"])
    assert eng.alloc.pinned_pages == 0
    assert eng.alloc.pages_in_use == 0


def test_radix_split_suffix_evictable():
    """Splitting an edge must leave the suffix node unreferenced —
    outstanding match leases belong to the prefix half — so eviction can
    fully drain the tree once all leases are released."""
    from repro.engine import RadixTree
    tree = RadixTree(page_size=4)
    tree.insert(list(range(8)), np.arange(8, dtype=np.int32))
    m, path = tree.match_prefix([0, 1, 2, 99])
    assert m.tolist() == [0, 1, 2]
    tree.insert([0, 1, 2, 99], np.asarray([0, 1, 2, 50], np.int32))
    tree.release(path)
    n_evicted = 0
    while tree.evict_one():
        n_evicted += 1
    assert n_evicted == 3          # both leaves, then the bare prefix
    assert tree.n_cached_tokens() == 0


def test_dedup_join_refcounts():
    """_dedup_join counts shared ancestor pages once and holds one ref
    per page; sources can be released under it."""
    pc = PoolConfig(n_layers=1, n_pages=32, page_size=4, n_kv_heads=1,
                    head_dim=8)
    alloc = PageAllocator(pc)
    ctx = IndexChain.fresh(alloc)
    ctx.reserve(5)
    a = ctx.fork(); a.reserve(3)
    b = ctx.fork(); b.reserve(2)
    merged = MedVerseEngine._dedup_join(None, [a, b])
    # ordered dedup: ctx prefix once, then each branch suffix
    assert merged.length == 5 + 3 + 2
    assert len(set(merged.idx.tolist())) == merged.length
    for pg in merged.pages:
        assert alloc.refs[pg] >= 2  # merged + at least one source
    ctx.release(); a.release(); b.release()
    assert alloc.pages_in_use > 0  # merged still holds everything
    merged.release()
    assert alloc.pages_in_use == 0


def test_chain_bucketing_bounds_pad_width(setup):
    """Short chains decode in small power-of-two buckets instead of the
    max_chain_len-wide pad, and the ladder is bounded."""
    tok, params = setup
    eng = make_engine(params, tok, plan_override=DIAMOND)
    assert eng.bucket_ladder() == [64, 128, 256]
    eng.generate(["q alpha beta"])
    assert eng.bucket_hist  # buckets recorded
    assert all(b <= 256 for b in eng.bucket_hist)
    assert min(eng.bucket_hist) < 256  # short chains paid a narrow pad
    # bucket arithmetic
    assert eng._chain_bucket(1) == 64
    assert eng._chain_bucket(64) == 64
    assert eng._chain_bucket(65) == 128
    assert eng._chain_bucket(256) == 256
    with pytest.raises(ValueError):
        eng._chain_bucket(257)


def test_warmup_precompiles_and_frees_scratch(setup):
    tok, params = setup
    eng = make_engine(params, tok, plan_override=DIAMOND)
    warmed = eng.warmup()
    assert warmed == [64, 128, 256]
    assert eng.alloc.pages_in_use == 0  # scratch page returned
    res = eng.generate(["q alpha beta"])[0]
    assert res.ok
