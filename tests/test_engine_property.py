"""Property tests on the engine's host-side invariants: fork/join chain
algebra over random DAG executions (no model needed — pure kvcache and
scheduler machinery)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import ColoredToken, PetriNet, PetriScheduler, ReasoningDAG
from repro.engine.kvcache import IndexChain, PageAllocator, PoolConfig


@st.composite
def random_dag(draw):
    n = draw(st.integers(min_value=1, max_value=8))
    deps = {}
    for v in range(n):
        k = draw(st.integers(min_value=0, max_value=min(2, v)))
        deps[v] = sorted(draw(st.lists(
            st.integers(min_value=0, max_value=v - 1),
            min_size=k, max_size=k, unique=True))) if v else []
    lens = [draw(st.integers(min_value=1, max_value=6)) for _ in range(n)]
    return deps, lens


@settings(max_examples=40, deadline=None)
@given(random_dag(), st.integers(min_value=1, max_value=9))
def test_chain_algebra_over_random_executions(dag_lens, ctx_len):
    """Simulate a full Petri execution with real index chains:
      (1) every chain's indices are unique (no token double-membership);
      (2) a child chain extends its parents' token sets exactly by its
          own appended tokens;
      (3) ordered-dedup join contains the union of predecessor tokens;
      (4) refcounted pages are all freed after release."""
    deps, lens = dag_lens
    dag = ReasoningDAG.from_deps(deps)
    net = PetriNet.from_dag(dag)
    pc = PoolConfig(n_layers=1, n_pages=512, page_size=4, n_kv_heads=1,
                    head_dim=4)
    alloc = PageAllocator(pc)
    ctx = IndexChain.fresh(alloc)
    ctx.reserve(ctx_len)
    sched = PetriScheduler(net, ColoredToken(history="ctx", kv_ref=ctx))
    chains = {}

    def execute(t, inputs):
        in_chains = [tok.kv_ref for tok in inputs]
        if len(in_chains) == 1:
            ch = in_chains[0].fork()
        else:
            # engine-style ordered dedup join
            seen, parts, pages = set(), [], set()
            for c in in_chains:
                arr = c.idx[:c.length]
                mask = np.array([int(s) not in seen for s in arr])
                seen.update(int(s) for s in arr)
                parts.append(arr[mask])
                pages |= c.pages
            ch = IndexChain(alloc)
            ch.idx = np.concatenate(parts).astype(np.int32)
            ch.length = len(ch.idx)
            ch.pages = pages
            for pg in pages:
                alloc.incref(pg)
        before = set(ch.idx.tolist())
        ch.reserve(lens[t.tid])
        after = set(ch.idx.tolist())
        # (1) uniqueness
        assert len(ch.idx) == len(after)
        # (2) extension property
        assert before <= after and len(after - before) == lens[t.tid]
        # (3) contains all ancestors' tokens
        for c in in_chains:
            assert set(c.idx[:c.length].tolist()) <= after
        chains[t.tid] = ch
        return ColoredToken(history=f"t{t.tid}", kv_ref=ch)

    sched.run(execute)
    assert sched.is_complete()
    # every chain includes the full ctx prefix
    ctx_set = set(ctx.idx.tolist())
    for ch in chains.values():
        assert ctx_set <= set(ch.idx.tolist())
    # (4) release everything -> all pages freed
    for ch in chains.values():
        ch.release()
    ctx.release()
    assert alloc.pages_in_use == 0
