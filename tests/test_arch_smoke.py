"""Per-architecture smoke tests: reduced variants (2-3 layers,
d_model<=512, <=4 experts), one forward + one train-grad step + one
decode step on CPU, asserting shapes and no NaNs."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (
    TopoBatch,
    decode_step,
    forward,
    init_cache,
    init_params,
    prefill_cross_kv,
    encoder_forward,
)

B, S = 2, 16


def make_inputs(cfg, key):
    ks = jax.random.split(key, 4)
    tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    topo = TopoBatch.linear(B, S)
    extra = {}
    if cfg.vision is not None:
        d = cfg.vision.embed_dim or cfg.d_model
        extra["image_embeds"] = jax.random.normal(
            ks[1], (B, cfg.vision.n_image_tokens, d), jnp.float32)
    if cfg.encoder is not None:
        extra["audio_embeds"] = jax.random.normal(
            ks[2], (B, cfg.encoder.n_ctx, cfg.d_model), jnp.float32)
    return tokens, topo, extra


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    tokens, topo, extra = make_inputs(cfg, key)
    logits, aux = jax.jit(
        lambda p, t: forward(p, t, topo, cfg, **extra)
    )(params, tokens)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN/Inf logits"
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_grad_smoke(arch):
    """One training step: masked CE + grad, finite values, nonzero grads."""
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    tokens, topo, extra = make_inputs(cfg, key)
    targets = jnp.roll(tokens, -1, axis=1)

    def loss_fn(p):
        logits, aux = forward(p, tokens, topo, cfg, **extra)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
        return nll.mean() + aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in leaves), (
        f"{arch}: non-finite grads"
    )
    total = sum(float(jnp.abs(g).sum()) for g in leaves)
    assert total > 0, f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_smoke(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    max_len = 32
    cache = init_cache(cfg, B, max_len)
    if cfg.encoder is not None:
        audio = jax.random.normal(key, (B, cfg.encoder.n_ctx, cfg.d_model),
                                  jnp.float32)
        enc_out = encoder_forward(params, audio, cfg)
        cache = prefill_cross_kv(params, cache, enc_out, cfg)

    step = jax.jit(
        lambda p, c, t, wi, qp: decode_step(p, c, t, wi, qp, cfg)
    )
    tok = jnp.zeros((B,), jnp.int32)
    for i in range(3):
        logits, cache = step(params, cache, tok,
                             jnp.int32(i), jnp.full((B,), i, jnp.int32))
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN decode"
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)


def test_decode_matches_forward_llama():
    """Greedy decode logits must match teacher-forced forward logits
    (cache correctness, linear topology)."""
    cfg = get_config("llama3.2-1b", smoke=True)
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    s = 8
    tokens = jax.random.randint(key, (B, s), 0, cfg.vocab_size)
    topo = TopoBatch.linear(B, s)
    full_logits, _ = forward(params, tokens, topo, cfg)

    cache = init_cache(cfg, B, s)
    for i in range(s):
        logits, cache = decode_step(
            params, cache, tokens[:, i], jnp.int32(i),
            jnp.full((B,), i, jnp.int32), cfg)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, i]),
            rtol=2e-4, atol=2e-4,
        )


def test_decode_matches_forward_rwkv():
    """Recurrent-state decode equals the scan-based forward for RWKV6."""
    cfg = get_config("rwkv6-3b", smoke=True)
    key = jax.random.PRNGKey(4)
    params = init_params(key, cfg)
    s = 8
    tokens = jax.random.randint(key, (B, s), 0, cfg.vocab_size)
    topo = TopoBatch.linear(B, s)
    full_logits, _ = forward(params, tokens, topo, cfg)
    cache = init_cache(cfg, B, s)
    for i in range(s):
        logits, cache = decode_step(
            params, cache, tokens[:, i], jnp.int32(i),
            jnp.full((B,), i, jnp.int32), cfg)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, i]),
            rtol=5e-4, atol=5e-4,
        )


def test_decode_matches_forward_recurrentgemma():
    cfg = get_config("recurrentgemma-2b", smoke=True)
    key = jax.random.PRNGKey(5)
    params = init_params(key, cfg)
    s = 8
    tokens = jax.random.randint(key, (B, s), 0, cfg.vocab_size)
    topo = TopoBatch.linear(B, s)
    full_logits, _ = forward(params, tokens, topo, cfg)
    cache = init_cache(cfg, B, s)
    for i in range(s):
        logits, cache = decode_step(
            params, cache, tokens[:, i], jnp.int32(i),
            jnp.full((B,), i, jnp.int32), cfg)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, i]),
            rtol=5e-4, atol=5e-4,
        )
