"""Speculative decoding: drafter unit tests against pure-Python
references, and the engine parity contract — temperature-0 output text
bit-identical with speculation on or off, across scheduler modes,
attention backends, repeated prompts (radix hits), forced preemption
mid-draft, and adversarially wrong drafters — with zero leaked pages
after rejected drafts."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokenizer import Tokenizer
from repro.engine import (
    EngineConfig,
    MedVerseEngine,
    NgramDrafter,
    RadixTree,
    make_drafter,
)
from repro.engine.spec import Drafter
from repro.models import init_params
from repro.serving import ContinuousScheduler, ServeRequest

CFG = get_config("medverse-7b", smoke=True)

DIAMOND = ("<Think> 1. q -> A -> C. 2. q -> B -> C. </Think> <Plan> "
           "<Outline> Transient Step 1: q -> A ; Dependency: [] </Outline> "
           "<Outline> Transient Step 2: q -> B ; Dependency: [] </Outline> "
           "<Outline> Transient Step 3: A , B -> C ; Dependency: [1, 2] "
           "</Outline> </Plan>")

FANOUT = ("<Plan> "
          "<Outline> Transient Step 1: alpha ; Dependency: [] </Outline> "
          "<Outline> Transient Step 2: beta ; Dependency: [] </Outline> "
          "<Outline> Transient Step 3: gamma ; Dependency: [] </Outline> "
          "</Plan>")

_LONG = " ".join(["gamma delta epsilon zeta eta theta iota kappa"] * 3)
MIXED_DEPTH = (
    "<Plan> "
    "<Outline> Transient Step 1: alpha ; Dependency: [] </Outline> "
    "<Outline> Transient Step 2: beta ; Dependency: [1] </Outline> "
    f"<Outline> Transient Step 3: {_LONG} ; Dependency: [] </Outline> "
    "</Plan>")


def make_tok():
    corpus = ["alpha beta gamma delta epsilon zeta eta theta iota kappa "
              "Transient Step 1: 2: 3: Dependency: [] [1] [2] [1, 2] "
              "A -> B ; C D q x y z"]
    return Tokenizer.train(corpus)


@pytest.fixture(scope="module")
def setup():
    tok = make_tok()
    params = init_params(jax.random.PRNGKey(0), CFG)
    return tok, params


def make_engine(params, tok, **kw):
    base = dict(max_slots=4, page_size=4, n_pages=512, max_chain_len=256,
                max_step_tokens=6, max_conclusion_tokens=6)
    base.update(kw)
    return MedVerseEngine(params, CFG, tok, EngineConfig(**base))


# ------------------------------------------------- drafter unit tests --


def _ref_ngram_propose(seqs, ctx, k, order, min_order):
    """Pure-Python reference for NgramDrafter.propose: longest trailing
    n-gram match, cross-request (most recently observed sequence, last
    occurrence within it) before self-context (most recent prior
    occurrence)."""
    for n in range(order, min_order - 1, -1):
        if len(ctx) < n:
            continue
        tail = list(ctx[-n:])
        for seq in reversed(seqs):
            hits = [i for i in range(len(seq) - n)
                    if list(seq[i:i + n]) == tail]
            if hits:
                out = seq[hits[-1] + n: hits[-1] + n + k]
                if out:
                    return out
        for i in range(len(ctx) - n - 1, -1, -1):
            if list(ctx[i:i + n]) == tail:
                out = ctx[i + n: i + n + k]
                if out:
                    return out
    return []


def test_ngram_drafter_matches_reference():
    rng = np.random.default_rng(0)
    d = NgramDrafter(order=4, min_order=2, max_sequences=8)
    seqs = [rng.integers(0, 6, size=rng.integers(5, 30)).tolist()
            for _ in range(6)]
    for s in seqs:
        d.observe(s)
    for _ in range(200):
        ctx = rng.integers(0, 6, size=rng.integers(2, 25)).tolist()
        k = int(rng.integers(1, 6))
        got = d.propose(ctx, k)
        want = _ref_ngram_propose(seqs, ctx, k, order=4, min_order=2)
        assert got == want, (ctx, k, got, want)


def test_ngram_drafter_self_context():
    d = NgramDrafter(order=3, min_order=2)
    # nothing observed: only the context itself can match
    ctx = [1, 2, 3, 9, 9, 1, 2, 3]
    assert d.propose(ctx, 2) == [9, 9]
    assert d.propose([1, 2, 3], 4) == []      # no prior occurrence


def test_ngram_drafter_eviction():
    d = NgramDrafter(order=2, min_order=2, max_sequences=2)
    d.observe([1, 2, 3, 4])
    d.observe([5, 6, 7, 8])
    assert d.propose([1, 2], 2) == [3, 4]
    d.observe([8, 9, 1, 5])     # evicts [1, 2, 3, 4]
    assert d.propose([1, 2], 2) == []
    assert d.propose([5, 6], 2) == [7, 8]


def test_radix_continuation():
    tree = RadixTree(page_size=4)
    tree.insert([1, 2, 3, 4, 5, 6], np.arange(6, dtype=np.int32))
    # mid-edge: rest of the edge
    assert tree.continuation([1, 2, 3], 3) == [4, 5, 6]
    assert tree.continuation([1, 2, 3], 2) == [4, 5]
    # full match: nothing cached beyond
    assert tree.continuation([1, 2, 3, 4, 5, 6], 3) == []
    # divergence before the history is consumed: no proposal
    assert tree.continuation([1, 2, 9], 3) == []
    assert tree.continuation([7], 3) == []
    # descends across a split into the most recently used child
    tree.insert([1, 2, 3, 7, 8], np.asarray([0, 1, 2, 40, 41], np.int32))
    assert tree.continuation([1, 2], 5) in ([3, 7, 8], [3, 4, 5, 6])
    # read-only: no refcounts taken, tree fully evictable
    while tree.evict_one():
        pass
    assert tree.n_cached_tokens() == 0


def test_make_drafter():
    assert make_drafter("ngram").name == "ngram"
    tree = RadixTree()
    d = make_drafter("radix", tree)
    assert d.name == "radix" and d.tree is tree
    with pytest.raises(ValueError):
        make_drafter("radix")          # needs the engine radix tree
    with pytest.raises(ValueError):
        make_drafter("medusa")


def test_radix_drafter_requires_radix_cache(setup):
    tok, params = setup
    with pytest.raises(ValueError, match="radix_cache"):
        make_engine(params, tok, speculative=True, drafter="radix",
                    radix_cache=False)


# --------------------------------------------------- engine parity -----


def _texts(results):
    return [(r.text, tuple(sorted(r.step_texts.items())), r.conclusion)
            for r in results]


@pytest.mark.parametrize("drafter", ["ngram", "radix"])
@pytest.mark.parametrize(
    "plan,async_frontier",
    [(DIAMOND, False), (DIAMOND, True), (FANOUT, False),
     (MIXED_DEPTH, True)],
    ids=["diamond-sync", "diamond-async", "fanout-sync", "mixed-async"])
def test_spec_parity_and_fewer_iters(setup, drafter, plan, async_frontier):
    """Temp-0 text identical with speculation on vs off on every
    scheduling path; repeated prompts (radix hits + warm drafter) finish
    in strictly fewer decode iterations."""
    tok, params = setup
    off = make_engine(params, tok, plan_override=plan,
                      async_frontier=async_frontier)
    on = make_engine(params, tok, plan_override=plan,
                     async_frontier=async_frontier,
                     speculative=True, drafter=drafter)
    prompts = ["q alpha beta", "q alpha beta", "q alpha beta"]
    r_off = [off.generate([p])[0] for p in prompts]
    r_on = [on.generate([p])[0] for p in prompts]
    assert _texts(r_on) == _texts(r_off)
    assert on.total_iters < off.total_iters
    assert on.spec_stats["accepted"] <= on.spec_stats["proposed"]
    assert on.spec_stats["tokens"] > on.spec_stats["steps"]
    # no pages leaked by rejected drafts
    assert on.alloc.used == 0


@pytest.mark.parametrize("drafter", ["ngram", "radix"])
def test_spec_parity_pallas_backend(setup, drafter):
    """Multi-token verification in one paged_decode call holds under the
    Pallas kernel's page-table masking too."""
    tok, params = setup
    off = make_engine(params, tok, plan_override=DIAMOND,
                      attention_backend="pallas", kernel_interpret=True)
    on = make_engine(params, tok, plan_override=DIAMOND,
                     attention_backend="pallas", kernel_interpret=True,
                     speculative=True, drafter=drafter)
    prompts = ["q alpha beta", "q alpha beta"]
    r_off = [off.generate([p])[0] for p in prompts]
    r_on = [on.generate([p])[0] for p in prompts]
    assert _texts(r_on) == _texts(r_off)
    assert on.total_iters < off.total_iters


class _WrongDrafter(Drafter):
    """Adversarial drafter: always proposes token 0 repeated — near
    guaranteed rejection, so every block rolls back its draft rows."""

    name = "wrong"

    def propose(self, ctx, k):
        return [0] * k


def test_rejected_drafts_roll_back_pages(setup, monkeypatch):
    """A drafter that is always wrong costs nothing but the wasted batch
    rows: output text identical, pages fully reclaimed, chain state
    byte-identical to the non-speculative run."""
    import repro.engine.engine as engine_mod
    tok, params = setup
    monkeypatch.setattr(engine_mod, "make_drafter",
                        lambda name, radix=None: _WrongDrafter())
    on = make_engine(params, tok, plan_override=DIAMOND,
                     speculative=True, draft_len=3)
    off = make_engine(params, tok, plan_override=DIAMOND)
    used0 = on.alloc.used
    r_on = on.generate(["q alpha beta"])[0]
    r_off = off.generate(["q alpha beta"])[0]
    assert r_on.text == r_off.text
    assert r_on.step_texts == r_off.step_texts
    assert on.spec_stats["proposed"] > 0
    # the near-certain rejections all rolled back cleanly
    assert on.spec_stats["accepted"] < on.spec_stats["proposed"]
    assert on.alloc.used == used0
    assert on.alloc.pages_in_use == on.alloc.used + on.alloc.pinned_pages
    # rejected-draft rollback never touches the page counters: the
    # lifetime alloc/free balance still explains occupancy exactly
    s = on.alloc.stats()
    assert s["allocs"] - s["frees"] == s["in_use"]
    assert s["in_use"] <= s["peak_in_use"] <= s["n_pages"]


@pytest.mark.parametrize("drafter", ["ngram", "radix"])
def test_spec_preemption_mid_draft(setup, drafter):
    """Forced preemption with speculation on: a pool small enough to
    evict mid-generation still completes every request with text
    identical to an unconstrained engine, and releases every page."""
    tok, params = setup
    big = make_engine(params, tok, plan_override=DIAMOND)
    ref = [big.generate([p])[0]
           for p in ["q alpha beta", "q gamma delta"]]
    tiny = make_engine(params, tok, plan_override=DIAMOND, n_pages=40,
                       speculative=True, drafter=drafter, draft_len=4)
    used0 = tiny.alloc.used
    res = tiny.generate(["q alpha beta", "q gamma delta"])
    assert _texts(res) == _texts(ref)
    assert tiny.preemptions > 0, "pool was not small enough to preempt"
    assert tiny.alloc.used == used0
    s = tiny.alloc.stats()
    assert s["allocs"] - s["frees"] == s["in_use"]
    assert s["pins"] - s["unpins"] == sum(tiny.alloc.pinned.values())
    assert s["in_use"] <= s["peak_in_use"] <= s["n_pages"]


def test_spec_serving_reports_draft_metrics(setup):
    """The continuous scheduler surfaces accepted-tokens-per-step and
    per-request draft counts when the engine speculates."""
    tok, params = setup
    eng = make_engine(params, tok, plan_override=DIAMOND,
                      speculative=True, drafter="ngram")
    sched = ContinuousScheduler(eng, clock="step")
    prompts = ["q alpha beta"] * 3
    rep = sched.run([ServeRequest(prompt=p, arrival=float(i))
                     for i, p in enumerate(prompts)])
    assert rep.n_completed == 3
    assert rep.spec_proposed > 0
    assert rep.spec_accepted == sum(
        r.metrics.n_drafted for r in sched.finished)
    assert rep.spec_acceptance > 0
    assert rep.tokens_per_step > 0
    assert rep.n_drafted > 0


def test_spec_off_reports_nan_acceptance(setup):
    tok, params = setup
    eng = make_engine(params, tok, plan_override=DIAMOND)
    sched = ContinuousScheduler(eng, clock="step")
    rep = sched.run([ServeRequest(prompt="q alpha beta")])
    assert rep.n_completed == 1
    assert rep.spec_proposed == 0 and rep.n_drafted == 0
    assert rep.spec_acceptance != rep.spec_acceptance  # NaN
