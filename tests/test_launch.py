"""Launch-layer tests: sharding specs are valid & divisible, the pjit
train step runs on a host mesh, and the dry-run entry point works in a
subprocess (fresh process so XLA device-count forcing doesn't leak)."""

import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, train_input_specs
from repro.launch.mesh import as_shardings, make_host_mesh, set_global_mesh
from repro.launch.sharding import batch_specs, cache_specs_tree, param_specs
from repro.models import init_cache, init_params
from repro.train import init_opt_state


class FakeMesh:
    """Looks enough like a 16x16 production mesh for spec validation."""
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible(arch):
    """Every sharded dim must divide by its mesh axes (full configs)."""
    cfg = get_config(arch)
    params = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    mesh = FakeMesh()
    specs = param_specs(cfg, params, mesh)
    leaves = jax.tree_util.tree_leaves(params)
    spec_leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves)
    n_sharded = 0
    for leaf, spec in zip(leaves, spec_leaves):
        assert len(tuple(spec)) == len(leaf.shape), (spec, leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            size = (np.prod([mesh.shape[a] for a in ax])
                    if isinstance(ax, tuple) else mesh.shape[ax])
            assert dim % size == 0, (arch, spec, leaf.shape)
            n_sharded += 1
    assert n_sharded > 0, f"{arch}: nothing sharded at all"


@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-v3-671b",
                                  "rwkv6-3b"])
def test_cache_specs_divisible(arch):
    cfg = get_config(arch)
    shape = SHAPES["decode_32k"]
    cache = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    mesh = FakeMesh()
    specs = cache_specs_tree(cfg, cache, mesh)
    for leaf, spec in zip(
            jax.tree_util.tree_leaves(cache),
            jax.tree_util.tree_leaves(specs,
                                      is_leaf=lambda x: isinstance(x, P))):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            size = (np.prod([mesh.shape[a] for a in ax])
                    if isinstance(ax, tuple) else mesh.shape[ax])
            assert dim % size == 0, (arch, spec, leaf.shape)


def test_pjit_train_step_host_mesh():
    """The full pjit train step executes on the 1x1 host mesh."""
    from repro.models import meshctx
    from repro.train import AdamWConfig, make_train_step

    cfg = get_config("llama3.2-1b", smoke=True)
    mesh = make_host_mesh()
    set_global_mesh(mesh)
    meshctx.set_mesh(mesh, ("data",), "model")
    try:
        params = init_params(jax.random.PRNGKey(0), cfg)
        opt = init_opt_state(params)
        pspecs = param_specs(cfg, params, mesh)
        batch = {
            "tokens": jnp.zeros((2, 16), jnp.int32),
            "targets": jnp.zeros((2, 16), jnp.int32),
            "loss_mask": jnp.ones((2, 16), jnp.float32),
            "seg_id": jnp.zeros((2, 16), jnp.int32),
            "layer_id": jnp.zeros((2, 16), jnp.int32),
            "pos_id": jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32),
                                       (2, 16)),
        }
        step = jax.jit(
            make_train_step(cfg, AdamWConfig()),
            in_shardings=as_shardings(
                mesh, (pspecs, {"mu": pspecs, "nu": pspecs, "step": P()},
                       batch_specs(cfg, batch, mesh))),
        )
        params2, opt2, metrics = step(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))
    finally:
        meshctx.set_mesh(None)


def test_dryrun_subprocess_skip_and_real():
    """The dry-run CLI: a skipped long_500k pair exits 0 with a skip
    record; a real decode pair compiles and reports roofline terms."""
    env = {**os.environ, "PYTHONPATH": "src"}
    out = "results/dryrun_test"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "llama3.2-1b", "--shape", "long_500k", "--out", out],
        capture_output=True, text=True, env=env, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    with open(os.path.join(out, "llama3.2-1b__long_500k__16_16.json")) as f:
        rec = json.load(f)
    assert rec["status"] == "skipped"

    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "llama3.2-1b", "--shape", "decode_32k", "--out", out],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    with open(os.path.join(out, "llama3.2-1b__decode_32k__16_16.json")) as f:
        rec = json.load(f)
    assert rec["status"] == "ok"
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert rec["roofline"]["collective_bytes"] > 0


def test_roofline_collective_parser():
    from repro.launch.roofline import parse_collectives

    hlo = """
      %ar = f32[16,128] all-reduce(f32[16,128] %x), replica_groups={}
      %ag.1 = bf16[8,256]{1,0} all-gather(bf16[4,256] %y), dimensions={0}
      %done = f32[2] all-reduce-done(f32[2] %h)
      %nothing = f32[4] add(f32[4] %a, f32[4] %b)
    """
    st = parse_collectives(hlo)
    assert st.bytes_by_kind["all-reduce"] == 16 * 128 * 4
    assert st.bytes_by_kind["all-gather"] == 8 * 256 * 2
    assert st.count_by_kind["all-reduce"] == 1  # -done not double counted
