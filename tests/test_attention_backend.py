"""Dense-vs-Pallas attention-backend parity.

The engine's ``attention_backend`` switch must not change observable
behaviour: temperature-0 generated text is identical across backends on
every DAG shape (wide fan-out, deep chain, diamond join, serial), with
local-attention windows, GQA head layouts (the test config has
``n_kv_heads < n_heads``), and radix-cache prefill hits. Logit-level
agreement is atol-bounded (flash renormalization reorders the float32
reduction — documented in ``paged_model``), and the pallas backend must
release pages exactly like the dense one.

Also pins the structural invariant the pallas decode path relies on:
every page an index chain references is referenced on a contiguous slot
prefix (``IndexChain.page_runs``), across fork, dedup-join, and radix
adoption.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokenizer import Tokenizer
from repro.engine import (EngineConfig, IndexChain, MedVerseEngine,
                          PageAllocator, PoolConfig, check_backend,
                          prefill_forward)
from repro.models import init_params
from repro.models.config import ATTN, LOCAL_ATTN, ModelConfig

CFG = get_config("medverse-7b", smoke=True)   # GQA: n_kv_heads < n_heads

WIDE = ("<Plan> "
        "<Outline> Transient Step 1: alpha ; Dependency: [] </Outline> "
        "<Outline> Transient Step 2: beta ; Dependency: [] </Outline> "
        "<Outline> Transient Step 3: gamma ; Dependency: [] </Outline> "
        "<Outline> Transient Step 4: delta ; Dependency: [] </Outline> "
        "</Plan>")
DEEP = ("<Plan> "
        "<Outline> Transient Step 1: alpha ; Dependency: [] </Outline> "
        "<Outline> Transient Step 2: beta ; Dependency: [1] </Outline> "
        "<Outline> Transient Step 3: gamma ; Dependency: [2] </Outline> "
        "</Plan>")
DIAMOND = ("<Plan> "
           "<Outline> Transient Step 1: q -> A ; Dependency: [] </Outline> "
           "<Outline> Transient Step 2: q -> B ; Dependency: [] </Outline> "
           "<Outline> Transient Step 3: A , B -> C ; Dependency: [1, 2] "
           "</Outline> </Plan>")
SERIAL = ("<Plan> "
          "<Outline> Transient Step 1: alpha ; Dependency: [] </Outline> "
          "</Plan>")

PLANS = {"wide": WIDE, "deep": DEEP, "diamond": DIAMOND, "serial": SERIAL}


def make_tok():
    corpus = ["alpha beta gamma delta epsilon zeta eta theta iota kappa "
              "Transient Step 1: 2: 3: 4: Dependency: [] [1] [2] [1, 2] "
              "A -> B ; C D q x y z"]
    return Tokenizer.train(corpus)


@pytest.fixture(scope="module")
def setup():
    tok = make_tok()
    params = init_params(jax.random.PRNGKey(0), CFG)
    return tok, params


def make_engine(params, tok, backend, cfg=CFG, **kw):
    base = dict(max_slots=4, page_size=4, n_pages=512, max_chain_len=256,
                max_step_tokens=6, max_conclusion_tokens=6,
                attention_backend=backend)
    base.update(kw)
    return MedVerseEngine(params, cfg, tok, EngineConfig(**base))


# ------------------------------------------------------ engine parity ------
@pytest.mark.parametrize("shape", sorted(PLANS))
def test_backend_parity_across_dag_shapes(setup, shape):
    """Temp-0 text (plan, every step, conclusion) is identical between
    backends on each DAG topology, and the pallas backend leaks no
    pages."""
    tok, params = setup
    plan = PLANS[shape]
    e_dense = make_engine(params, tok, "dense", plan_override=plan)
    e_pallas = make_engine(params, tok, "pallas", plan_override=plan)
    used0 = e_pallas.alloc.used
    rd = e_dense.generate(["q alpha beta"])[0]
    rp = e_pallas.generate(["q alpha beta"])[0]
    assert rd.text == rp.text
    assert rd.step_texts == rp.step_texts
    assert rd.conclusion == rp.conclusion
    # no page leak under the pallas decode path; pinned radix pages are
    # cache, fully accounted
    assert e_pallas.alloc.used == used0
    assert (e_pallas.alloc.pages_in_use
            == e_pallas.alloc.used + e_pallas.alloc.pinned_pages)
    assert e_pallas.page_bucket_hist  # the kernel path actually ran


@pytest.mark.parametrize("async_frontier", [False, True])
def test_backend_parity_scheduler_modes(setup, async_frontier):
    """Backends agree under both sync and async-frontier scheduling."""
    tok, params = setup
    kw = dict(plan_override=DIAMOND, async_frontier=async_frontier)
    rd = make_engine(params, tok, "dense", **kw).generate(["q alpha"])[0]
    rp = make_engine(params, tok, "pallas", **kw).generate(["q alpha"])[0]
    assert rd.text == rp.text


def test_backend_parity_radix_hit(setup):
    """A radix-cached re-prefill (chain adopts cached pool slots, prefill
    recomputes only the tail) yields the same text under pallas."""
    tok, params = setup
    prompt = "q alpha beta gamma delta epsilon zeta eta theta"
    e_pallas = make_engine(params, tok, "pallas", plan_override=DIAMOND)
    cold = e_pallas.generate([prompt])[0]
    assert e_pallas.radix.misses >= 1
    warm = e_pallas.generate([prompt])[0]
    assert e_pallas.radix.hits >= 1
    assert warm.text == cold.text
    e_dense = make_engine(params, tok, "dense", plan_override=DIAMOND)
    assert e_dense.generate([prompt])[0].text == warm.text


def test_backend_parity_preemption(setup):
    """Preemption + re-prefill under page pressure is backend-agnostic:
    both backends finish every request with identical text."""
    tok, params = setup
    prompts = ["q alpha beta", "q beta gamma", "q gamma delta"]
    kw = dict(plan_override=DIAMOND, n_pages=56, radix_cache=False)
    e_dense = make_engine(params, tok, "dense", **kw)
    e_pallas = make_engine(params, tok, "pallas", **kw)
    rd = e_dense.generate(prompts)
    rp = e_pallas.generate(prompts)
    assert [r.text for r in rd] == [r.text for r in rp]
    assert e_pallas.alloc.used == 0


def test_local_attention_window_parity(setup):
    """LOCAL_ATTN layers (sliding window on adaptive positions) agree
    across backends through prefill and paged decode."""
    tok, _ = setup
    cfg = ModelConfig(
        name="local-mix", arch_type="dense",
        vocab_size=CFG.vocab_size, d_model=64, n_layers=2,
        n_heads=4, n_kv_heads=2, d_ff=128, head_dim=16,
        pattern_unit=(ATTN, LOCAL_ATTN), sliding_window=8,
        dtype="float32", scan_layers=False, remat=False, max_seq_len=512)
    params = init_params(jax.random.PRNGKey(1), cfg)
    kw = dict(plan_override=DIAMOND)
    rd = make_engine(params, tok, "dense", cfg=cfg, **kw).generate(["q a"])[0]
    rp = make_engine(params, tok, "pallas", cfg=cfg, **kw).generate(["q a"])[0]
    assert rd.text == rp.text


# ------------------------------------------------------ logit parity -------
def test_prefill_logits_atol(setup):
    """Prefill logits agree to float32-rounding atol between the dense
    SDPA and the chunked DAG flash kernel (GQA layout)."""
    tok, params = setup
    ids = tok.encode("q alpha beta gamma delta", bos=True)
    n = len(ids)
    ids_p = np.zeros((64,), np.int32)
    ids_p[:n] = ids
    pos = np.arange(64, dtype=np.int32)
    outs = {}
    for backend in ("dense", "pallas"):
        logits, ks, vs = prefill_forward(
            params, jnp.asarray(ids_p)[None], jnp.asarray(pos)[None],
            CFG, jnp.int32(n), backend=backend, interpret=True)
        outs[backend] = (np.asarray(logits), np.asarray(ks), np.asarray(vs))
    np.testing.assert_allclose(outs["dense"][0], outs["pallas"][0],
                               rtol=2e-4, atol=2e-4)
    # K/V written to the pool must match as tightly: decode consumes them
    np.testing.assert_allclose(outs["dense"][1], outs["pallas"][1],
                               rtol=2e-4, atol=2e-4)


def test_check_backend_rejects():
    with pytest.raises(ValueError):
        check_backend(CFG, "cuda")
    import dataclasses as dc
    capped = dc.replace(CFG, attn_logit_softcap=30.0)
    with pytest.raises(NotImplementedError):
        check_backend(capped, "pallas")
    check_backend(capped, "dense")  # dense supports the softcap


# ------------------------------------------- page-prefix invariant ---------
def _assert_prefix_runs(chain: IndexChain):
    ps = chain.alloc.pc.page_size
    pages, valid = chain.page_runs()
    assert int(valid.sum()) == chain.length
    idx = chain.idx[: chain.length]
    for pg, cnt in zip(pages, valid):
        slots = sorted(int(s) for s in idx[idx // ps == pg])
        assert slots == list(range(pg * ps, pg * ps + cnt)), (
            f"page {pg}: chain references {slots}, not a prefix of "
            f"length {cnt}")


def test_page_runs_prefix_invariant_fork_join_adopt():
    """The pallas decode path attends to the leading ``valid`` slots of
    each table page; that equals the chain's slot set only because every
    referenced page is a contiguous prefix. Exercise all chain
    constructors."""
    pc = PoolConfig(n_layers=1, n_pages=64, page_size=4, n_kv_heads=1,
                    head_dim=8)
    alloc = PageAllocator(pc)
    ctx = IndexChain.fresh(alloc)
    ctx.reserve(6)                       # 1.5 pages
    _assert_prefix_runs(ctx)
    a = ctx.fork(); a.reserve(3)
    b = ctx.fork(); b.reserve(5)
    _assert_prefix_runs(a)
    _assert_prefix_runs(b)
    merged = MedVerseEngine._dedup_join(None, [a, b])
    _assert_prefix_runs(merged)
    # radix-style adoption of a partial prefix, then fresh appends
    c = IndexChain.fresh(alloc)
    c.adopt(ctx.idx[:5])
    c.reserve(2)
    _assert_prefix_runs(c)
    # joined chain keeps appending into its own fresh page
    merged.reserve(3)
    _assert_prefix_runs(merged)
    # rollback keeps the prefix property
    merged.pop_slot()
    _assert_prefix_runs(merged)
    for ch in (ctx, a, b, c, merged):
        ch.release()
    assert alloc.pages_in_use == 0
