"""Training-layer tests: loss masking semantics, optimizer behaviour,
checkpoint roundtrip, and a short end-to-end loss-decrease run."""

import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data import Corpus, encode_example, make_batches
from repro.models import init_params
from repro.models.config import ATTN, ModelConfig
from repro.train import (
    AdamWConfig,
    TrainConfig,
    adamw_update,
    init_opt_state,
    load_checkpoint,
    lr_schedule,
    make_train_step,
    masked_ce,
    save_checkpoint,
    train_model,
)


def tiny_cfg(vocab=256):
    return ModelConfig(
        name="tiny", arch_type="dense", vocab_size=vocab, d_model=64,
        n_layers=2, n_heads=2, n_kv_heads=2, d_ff=128, head_dim=32,
        pattern_unit=(ATTN,), dtype="float32", scan_layers=False,
        remat=False, max_seq_len=256)


def test_masked_ce_ignores_masked():
    logits = jnp.zeros((1, 4, 8))
    targets = jnp.asarray([[1, 2, 3, 4]])
    m1 = masked_ce(logits, targets, jnp.asarray([[1.0, 1, 1, 1]]))
    m2 = masked_ce(logits, targets, jnp.asarray([[1.0, 0, 0, 1]]))
    np.testing.assert_allclose(float(m1), float(m2), rtol=1e-6)
    assert float(masked_ce(logits, targets, jnp.zeros((1, 4)))) == 0.0


def test_lr_schedule_shape():
    cfg = AdamWConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_schedule(jnp.asarray(5), cfg)) == pytest.approx(0.5)
    assert float(lr_schedule(jnp.asarray(10), cfg)) == pytest.approx(1.0)
    assert float(lr_schedule(jnp.asarray(100), cfg)) == pytest.approx(
        cfg.min_lr_ratio)


def test_adamw_moves_params():
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    grads = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    st = init_opt_state(params)
    new, st2, m = adamw_update(params, grads, st, AdamWConfig(
        learning_rate=0.1, warmup_steps=0, total_steps=10))
    assert float(jnp.abs(new["w"] - params["w"]).sum()) > 0
    assert int(st2["step"]) == 1
    assert float(m["grad_norm"]) > 0


def test_checkpoint_roundtrip():
    cfg = tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.msgpack")
        save_checkpoint(path, params, step=7, metadata={"a": 1})
        like = init_params(jax.random.PRNGKey(1), cfg)
        restored, step, meta = load_checkpoint(path, like)
        assert step == 7 and meta == {"a": 1}
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_loss_decreases_end_to_end():
    """Three epochs on a small corpus must cut CE by >40%."""
    corpus = Corpus.build(n_items=60, n_clusters=12, seed=11)
    cfg = tiny_cfg(corpus.tokenizer.vocab_size + 16)
    _, hist = train_model(
        cfg, corpus, TrainConfig(epochs=3, batch_size=4, seq_len=224,
                                 log_every=5, learning_rate=3e-3))
    assert hist[-1]["ce"] < 0.6 * hist[0]["ce"], hist


def test_dag_vs_causal_training_differ():
    """The attention mask must actually change the learning problem:
    gradients under DAG metadata differ from causal metadata."""
    corpus = Corpus.build(n_items=40, n_clusters=10, seed=13)
    ex = next(e for e in corpus.train if len(e.step_texts) >= 2)
    cfg = tiny_cfg(corpus.tokenizer.vocab_size + 16)
    params = init_params(jax.random.PRNGKey(0), cfg)
    step = make_train_step(cfg, AdamWConfig())
    opt = init_opt_state(params)
    encs = {}
    for causal in (False, True):
        enc = encode_example(ex, corpus.tokenizer, causal=causal)
        batch = make_batches([enc], 1, 224)[0]
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        _, _, metrics = step(params, opt, jb)
        encs[causal] = float(metrics["ce"])
    assert encs[False] != encs[True]
