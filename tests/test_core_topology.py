"""Tests for topology metadata + DAG attention masks (paper Eq. 3)."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    PAD_SEG,
    ReasoningDAG,
    SegmentSpec,
    ancestor_attention_allowed,
    build_topology,
    dag_attention_allowed,
    dag_depth_tokens,
    linear_topology,
    mask_bias,
    sliding_window_allowed,
    topology_from_dag,
)


def diamond():
    return ReasoningDAG.from_deps({0: [], 1: [0], 2: [0], 3: [1, 2]})


def make_diamond_topo(prefix=4, step=3, conc=2):
    dag = diamond()
    topo, order = topology_from_dag(
        dag, prefix_len=prefix, step_lens={t: step for t in dag.nodes},
        conclusion_len=conc,
    )
    return dag, topo, order


def test_adaptive_positions_fork_alignment():
    """Steps 1 and 2 (same frontier) share a start index (fork alignment);
    the join step starts at the max predecessor end (Sec. 4.2)."""
    _, topo, order = make_diamond_topo(prefix=4, step=3, conc=2)
    # packed: prefix(4) step0(3) | step1(3) step2(3) | step3(3) conc(2)
    assert order == [0, 1, 2, 3]
    pos = topo.pos_id
    # prefix positions 0..3
    assert list(pos[:4]) == [0, 1, 2, 3]
    # layer 1 = step0 starts at 4
    assert list(pos[4:7]) == [4, 5, 6]
    # layer 2 = steps 1 and 2 both start at 7 (fork alignment)
    assert list(pos[7:10]) == [7, 8, 9]
    assert list(pos[10:13]) == [7, 8, 9]
    # layer 3 = join step starts at max end = 10
    assert list(pos[13:16]) == [10, 11, 12]
    # conclusion starts after join
    assert list(pos[16:18]) == [13, 14]
    assert dag_depth_tokens(topo) == 15  # critical path < total tokens (18)


def test_dag_mask_blocks_same_layer_siblings():
    _, topo, _ = make_diamond_topo()
    allowed = np.asarray(
        dag_attention_allowed(jnp.asarray(topo.seg_id), jnp.asarray(topo.layer_id))
    )
    # token 7 (step1 first token) vs token 10..12 (step2): same layer,
    # different seg -> blocked both directions (within causal order)
    assert not allowed[10, 7]
    assert not allowed[12, 8]
    # step1 token can see prefix and step0
    assert allowed[7, 0] and allowed[7, 4]
    # join step (tokens 13..15) can see both branches (paper mask: earlier
    # layers are visible)
    assert allowed[13, 8] and allowed[13, 11]
    # causality in packed order
    assert not allowed[7, 10]
    # diagonal allowed
    assert allowed[9, 9]


def test_ancestor_mask_stricter():
    dag, topo, _ = make_diamond_topo()
    seg = jnp.asarray(topo.seg_id)
    paper = np.asarray(dag_attention_allowed(seg, jnp.asarray(topo.layer_id)))
    strict = np.asarray(ancestor_attention_allowed(seg, jnp.asarray(topo.seg_visible)))
    # strict is a subset of paper-allowed for cross-layer non-ancestors:
    # here the diamond has no non-ancestor earlier layer, so add one:
    assert (strict & ~paper).sum() == 0 or True  # strictness checked below
    # everything strict allows, paper allows too (on this diamond)
    assert not (strict & ~paper).any()


def test_ancestor_mask_blocks_non_ancestor_earlier_layer():
    # 0->2, 1 independent; layers [[0,1],[2]]; 2 depends only on 0.
    dag = ReasoningDAG.from_deps({0: [], 1: [], 2: [0]})
    topo, order = topology_from_dag(
        dag, prefix_len=2, step_lens={0: 2, 1: 2, 2: 2}, conclusion_len=1
    )
    seg = jnp.asarray(topo.seg_id)
    paper = np.asarray(dag_attention_allowed(seg, jnp.asarray(topo.layer_id)))
    strict = np.asarray(ancestor_attention_allowed(seg, jnp.asarray(topo.seg_visible)))
    # packed: prefix(2) step0(2) step1(2) step2(2) conc(1)
    # step2 tokens are 6,7; step1 tokens are 4,5 (non-ancestor, earlier layer)
    assert paper[6, 4]        # paper mask allows earlier layer
    assert not strict[6, 4]   # strict ancestor mask blocks it
    assert strict[6, 2]       # ancestor (step0) visible
    assert strict[8, 4]       # conclusion sees everything


def test_padding_masked():
    topo = linear_topology(5).pad_to(8)
    allowed = np.asarray(
        dag_attention_allowed(jnp.asarray(topo.seg_id), jnp.asarray(topo.layer_id))
    )
    assert not allowed[6, 6]  # pad rows/cols fully masked
    assert not allowed[6, 2]
    assert allowed[4, 2]


def test_mask_bias_values():
    topo = linear_topology(4)
    allowed = dag_attention_allowed(
        jnp.asarray(topo.seg_id), jnp.asarray(topo.layer_id)
    )
    bias = np.asarray(mask_bias(allowed))
    assert bias[2, 1] == 0.0
    assert bias[1, 2] < -1e29


def test_sliding_window_composition():
    _, topo, _ = make_diamond_topo(prefix=6, step=3, conc=2)
    win = np.asarray(sliding_window_allowed(jnp.asarray(topo.pos_id), window=4))
    # prefix token 5 (pos 5) cannot see pos 0/1 with window 4
    assert not win[5, 0]
    assert win[5, 2]
    # fork-aligned siblings have *equal* positions; window never lets a
    # token see a "future" adaptive position
    pos = topo.pos_id
    ii, jj = np.where(win)
    assert (pos[jj] <= pos[ii]).all()


@st.composite
def random_dag_and_lens(draw):
    n = draw(st.integers(min_value=1, max_value=8))
    deps = {}
    for v in range(n):
        k = draw(st.integers(min_value=0, max_value=min(2, v)))
        deps[v] = sorted(
            draw(
                st.lists(
                    st.integers(min_value=0, max_value=v - 1),
                    min_size=k,
                    max_size=k,
                    unique=True,
                )
            )
        ) if v else []
    lens = {v: draw(st.integers(min_value=1, max_value=4)) for v in range(n)}
    prefix = draw(st.integers(min_value=1, max_value=5))
    conc = draw(st.integers(min_value=1, max_value=3))
    return deps, lens, prefix, conc


@settings(max_examples=50, deadline=None)
@given(random_dag_and_lens())
def test_property_topology_invariants(data):
    """System invariants: (1) same-frontier segments share a start pos;
    (2) a segment's start pos >= every predecessor segment's end pos;
    (3) the paper mask never allows attention across same-layer different
    segments; (4) pos ids are contiguous within a segment."""
    deps, lens, prefix, conc = data
    dag = ReasoningDAG.from_deps(deps)
    topo, order = topology_from_dag(dag, prefix, lens, conc)
    seg, lay, pos = topo.seg_id, topo.layer_id, topo.pos_id
    # (1) & (4)
    for s in np.unique(seg):
        idx = np.where(seg == s)[0]
        p = pos[idx]
        assert (np.diff(p) == 1).all()
    starts = {}
    ends = {}
    for s in np.unique(seg):
        idx = np.where(seg == s)[0]
        starts[int(s)] = int(pos[idx].min())
        ends[int(s)] = int(pos[idx].max()) + 1
        layer_of = int(lay[idx[0]])
        for s2 in np.unique(seg):
            idx2 = np.where(seg == s2)[0]
            if int(lay[idx2[0]]) == layer_of:
                assert int(pos[idx2].min()) == starts[int(s)]
    # (2) predecessors end before dependents start
    for t in dag.nodes:
        for p_ in dag.predecessors(t):
            assert ends[p_ + 1] <= starts[t + 1]
    # (3)
    allowed = np.asarray(
        dag_attention_allowed(jnp.asarray(seg), jnp.asarray(lay))
    )
    same_layer = lay[:, None] == lay[None, :]
    diff_seg = seg[:, None] != seg[None, :]
    assert not (allowed & same_layer & diff_seg).any()
