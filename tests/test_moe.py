"""MoE layer tests: entry-scatter dispatch vs the dense oracle, capacity
drop behaviour, router flavors, and aux-loss sanity."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.moe import _route, init_moe, moe_ffn, moe_ref
from repro.models.config import MoEConfig


def _cfg(router="softmax", cap=4.0, k=2, e=4):
    base = get_config("dbrx-132b", smoke=True)
    return dataclasses.replace(
        base,
        moe=MoEConfig(n_experts=e, top_k=k, d_ff_expert=64,
                      router_scoring=router, capacity_factor=cap),
        d_model=32,
    )


@pytest.mark.parametrize("router", ["softmax", "sigmoid"])
def test_dispatch_matches_dense_oracle(router):
    """With ample capacity, the scatter/grouped-matmul dispatch must equal
    the dense all-experts oracle exactly."""
    cfg = _cfg(router=router, cap=8.0)
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model))
    y, aux = moe_ffn(p, x, cfg)
    y_ref = moe_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)
    assert float(aux) > 0


def test_capacity_drops_tokens():
    """With capacity 1 token/expert, most contributions are dropped —
    outputs shrink toward zero but stay finite."""
    cfg = _cfg(cap=0.01)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, _ = moe_ffn(p, x, cfg)
    y_full, _ = moe_ffn(p, x, dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)))
    assert np.isfinite(np.asarray(y)).all()
    assert float(jnp.abs(y).sum()) < float(jnp.abs(y_full).sum())


def test_router_weights_normalized():
    cfg = _cfg(router="sigmoid")
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, cfg.d_model))
    w, ids, aux = _route(x, p["router"], cfg.moe)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert ids.shape == (8, cfg.moe.top_k)
    assert int(ids.max()) < cfg.moe.n_experts


def test_shared_expert_contributes():
    cfg = get_config("deepseek-v3-671b", smoke=True)
    from repro.models.moe import init_moe as im
    p = im(jax.random.PRNGKey(0), cfg)
    assert "shared" in p
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model))
    y, _ = moe_ffn(p, x, cfg)
    # zeroing the shared expert must change the output
    p2 = dict(p)
    p2["shared"] = jax.tree_util.tree_map(jnp.zeros_like, p["shared"])
    y2, _ = moe_ffn(p2, x, cfg)
    assert float(jnp.abs(y - y2).max()) > 0


def test_moe_grad_flows_to_router():
    cfg = _cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))

    def loss(p):
        y, aux = moe_ffn(p, x, cfg)
        return jnp.square(y).mean() + aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["w_in"]).sum()) > 0
