"""KV quantization tests: int8 page-pool round-trip and scale
semantics, engine temp-0 parity against the f32 pool on both attention
backends, exact 4x byte accounting, and the capacity side — the same
byte budget buys ~4x the pages and strictly fewer preemptions under
page pressure."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokenizer import Tokenizer
from repro.engine import EngineConfig, MedVerseEngine, PoolConfig
from repro.engine.kvcache import (
    init_pool,
    pages_for_budget,
    quant_write_span,
)
from repro.engine.paged_model import decode_attention_dense
from repro.models import init_params
from repro.serving import ContinuousScheduler, ServeRequest

CFG = get_config("medverse-7b", smoke=True)

DIAMOND = ("<Plan> "
           "<Outline> Transient Step 1: q -> A ; Dependency: [] </Outline> "
           "<Outline> Transient Step 2: q -> B ; Dependency: [] </Outline> "
           "<Outline> Transient Step 3: A , B -> C ; Dependency: [1, 2] "
           "</Outline> </Plan>")


def make_tok():
    corpus = ["alpha beta gamma delta epsilon zeta eta theta iota kappa "
              "Transient Step 1: 2: 3: Dependency: [] [1] [2] [1, 2] "
              "A -> B ; C D q x y z"]
    return Tokenizer.train(corpus)


@pytest.fixture(scope="module")
def setup():
    tok = make_tok()
    params = init_params(jax.random.PRNGKey(0), CFG)
    return tok, params


def make_engine(params, tok, **kw):
    # kv_dtype pinned to f32 so the f32 side of every comparison stays
    # f32 even on the ENGINE_KV_DTYPE=int8 CI leg; int8 tests override
    base = dict(max_slots=4, page_size=4, n_pages=512, max_chain_len=256,
                max_step_tokens=6, max_conclusion_tokens=6, kv_dtype="f32")
    base.update(kw)
    return MedVerseEngine(params, CFG, tok, EngineConfig(**base))


# ------------------------------------------------------------- pool -------

def _quant_pc(**kw):
    base = dict(n_layers=2, n_pages=8, page_size=4, n_kv_heads=2,
                head_dim=8, kv_dtype="int8")
    base.update(kw)
    return PoolConfig(**base)


def test_pool_roundtrip_within_quant_error():
    """Write f32 rows into the int8 pool and dequantize: every element
    stays within the compounded quantization error. One bin is
    absmax/127 per (layer, page, kv_head); a row quantized at write
    time carries <= 0.5 bin, and every later same-page write that grows
    the scale requantizes it in place for up to another 0.5 bin each —
    at most page_size - 1 times."""
    pc = _quant_pc()
    pool = init_pool(pc)
    rng = np.random.default_rng(0)
    s = 10   # spans 3 pages, last one partial
    kv_k = jnp.asarray(rng.normal(size=(pc.n_layers, s, pc.n_kv_heads,
                                        pc.head_dim)), jnp.float32)
    kv_v = jnp.asarray(rng.normal(size=kv_k.shape), jnp.float32)
    slots = jnp.arange(s, dtype=jnp.int32)
    pk, pv, ks, vs = quant_write_span(
        pool["k"], pool["v"], pool["k_scale"], pool["v_scale"],
        kv_k, kv_v, slots, pc.page_size)
    pages = np.arange(s) // pc.page_size
    deq_k = np.asarray(pk, np.float32)[:, :s] * np.asarray(
        ks)[:, pages][:, :, :, None]
    deq_v = np.asarray(pv, np.float32)[:, :s] * np.asarray(
        vs)[:, pages][:, :, :, None]
    bins = 0.5 * pc.page_size + 0.01   # write + up to S-1 requants
    tol_k = np.asarray(ks)[:, pages][:, :, :, None] * bins + 1e-7
    tol_v = np.asarray(vs)[:, pages][:, :, :, None] * bins + 1e-7
    assert np.all(np.abs(deq_k - np.asarray(kv_k)) <= tol_k)
    assert np.all(np.abs(deq_v - np.asarray(kv_v)) <= tol_v)


def test_scale_grows_and_requantizes_in_place():
    """A mid-page write with a larger absmax grows the page scale and
    requantizes the rows already stored there — the earlier row stays
    within the (coarser) new bin, and the scale never shrinks."""
    pc = _quant_pc(n_layers=1)
    pool = init_pool(pc)
    small = np.full((1, 1, pc.n_kv_heads, pc.head_dim), 0.1, np.float32)
    big = np.full((1, 1, pc.n_kv_heads, pc.head_dim), 10.0, np.float32)
    pk, pv, ks, vs = quant_write_span(
        pool["k"], pool["v"], pool["k_scale"], pool["v_scale"],
        jnp.asarray(small), jnp.asarray(small),
        jnp.asarray([0], jnp.int32), pc.page_size)
    s0 = float(np.asarray(ks)[0, 0, 0])
    assert s0 == pytest.approx(0.1 / 127.0)
    pk, pv, ks, vs = quant_write_span(
        pk, pv, ks, vs, jnp.asarray(big), jnp.asarray(big),
        jnp.asarray([1], jnp.int32), pc.page_size)
    s1 = float(np.asarray(ks)[0, 0, 0])
    assert s1 == pytest.approx(10.0 / 127.0)
    deq0 = np.asarray(pk, np.float32)[0, 0] * s1
    assert np.all(np.abs(deq0 - 0.1) <= s1 * 0.51 + 1e-7)


def test_offset_zero_write_resets_page_scale():
    """Reusing a freed page (offset-0 write) must wipe the stale scale,
    not max against it — otherwise one old outlier page would coarsen
    every future resident forever."""
    pc = _quant_pc(n_layers=1)
    pool = init_pool(pc)
    big = np.full((1, 1, pc.n_kv_heads, pc.head_dim), 10.0, np.float32)
    small = np.full((1, 1, pc.n_kv_heads, pc.head_dim), 0.1, np.float32)
    pk, pv, ks, vs = quant_write_span(
        pool["k"], pool["v"], pool["k_scale"], pool["v_scale"],
        jnp.asarray(big), jnp.asarray(big),
        jnp.asarray([0], jnp.int32), pc.page_size)
    pk, pv, ks, vs = quant_write_span(
        pk, pv, ks, vs, jnp.asarray(small), jnp.asarray(small),
        jnp.asarray([0], jnp.int32), pc.page_size)
    assert float(np.asarray(ks)[0, 0, 0]) == pytest.approx(0.1 / 127.0)


def test_dense_gather_dequant_matches_prescaled_pool():
    """The dense backend's in-gather dequant (int8 * scale at the page
    index) computes on exactly the values an f32 pool holding the
    dequantized rows would — same attention output bit-for-bit."""
    pc = _quant_pc(n_layers=1)
    pool = init_pool(pc)
    rng = np.random.default_rng(1)
    s = 7
    kv_k = jnp.asarray(rng.normal(size=(1, s, pc.n_kv_heads, pc.head_dim)),
                       jnp.float32)
    kv_v = jnp.asarray(rng.normal(size=kv_k.shape), jnp.float32)
    slots = jnp.arange(s, dtype=jnp.int32)
    pk, pv, ks, vs = quant_write_span(
        pool["k"], pool["v"], pool["k_scale"], pool["v_scale"],
        kv_k, kv_v, slots, pc.page_size)
    pos = pool["pos"].at[:s].set(jnp.arange(s, dtype=jnp.int32))
    q = jnp.asarray(rng.normal(size=(1, 1, 4, pc.head_dim)), jnp.float32)
    ci = jnp.arange(8, dtype=jnp.int32)[None, :]
    cl = jnp.asarray([s], jnp.int32)
    qp = jnp.asarray([s - 1], jnp.int32)
    out_q = decode_attention_dense(
        q, pk[0], pv[0], pos, ci, cl, qp,
        k_scale=ks[0], v_scale=vs[0], page_size=pc.page_size)
    pages = jnp.arange(pc.n_slots) // pc.page_size
    deq_k = pk[0].astype(jnp.float32) * ks[0][pages][:, :, None]
    deq_v = pv[0].astype(jnp.float32) * vs[0][pages][:, :, None]
    out_f = decode_attention_dense(q, deq_k, deq_v, pos, ci, cl, qp)
    np.testing.assert_array_equal(np.asarray(out_q), np.asarray(out_f))


# ----------------------------------------------------------- engine -------

def test_kv_dtype_validated(setup):
    tok, params = setup
    with pytest.raises(ValueError, match="kv_dtype"):
        make_engine(params, tok, kv_dtype="int4")


@pytest.mark.parametrize("backend", ["dense", "pallas"])
def test_temp0_parity_and_exact_byte_ratio(setup, backend):
    """int8 KV pages must not change a single temp-0 token on either
    backend, and the analytic KV byte counters must show exactly 4x
    fewer bytes (1-byte cells vs 4-byte f32 — no slack anywhere)."""
    tok, params = setup
    prompts = ["alpha beta gamma delta q x",
               "kappa iota theta eta zeta epsilon delta gamma beta q"]
    e_f = make_engine(params, tok, attention_backend=backend)
    e_q = make_engine(params, tok, attention_backend=backend,
                      kv_dtype="int8")
    r_f = e_f.generate(prompts, plans=[DIAMOND, DIAMOND])
    r_q = e_q.generate(prompts, plans=[DIAMOND, DIAMOND])
    assert [r.text for r in r_f] == [r.text for r in r_q]
    assert e_f.total_iters == e_q.total_iters
    for field in ("kv_write_bytes", "kv_read_bytes"):
        f, q = e_f.cost.total(field), e_q.cost.total(field)
        assert f > 0 and q * 4 == f, (field, q, f)


def test_no_page_leak_int8(setup):
    tok, params = setup
    eng = make_engine(params, tok, kv_dtype="int8", radix_cache=False)
    eng.generate(["alpha beta gamma q"], plans=[DIAMOND])
    assert eng.alloc.used == 0
    st = eng.alloc.stats()
    assert st["allocs"] - st["frees"] == 0


# --------------------------------------------------------- capacity -------

def _probe_pc(page_size: int, kv_dtype: str) -> PoolConfig:
    return PoolConfig(
        n_layers=CFG.n_layers, n_pages=1, page_size=page_size,
        n_kv_heads=CFG.n_kv_heads, head_dim=CFG.resolved_head_dim,
        dtype=CFG.dtype, kv_dtype=kv_dtype)


def test_byte_budget_buys_4x_pages(setup):
    """`kv_pool_bytes` sizes the pool in bytes: int8 (plus its scale
    rows) packs >= 3.5x the pages of f32 into the same budget, and the
    engine's live pool reflects it."""
    tok, params = setup
    budget = 64 * _probe_pc(4, "f32").page_bytes
    e_f = make_engine(params, tok, kv_pool_bytes=budget)
    e_q = make_engine(params, tok, kv_pool_bytes=budget, kv_dtype="int8")
    assert e_f.pc.n_pages == 64
    assert e_q.pc.n_pages >= int(3.5 * e_f.pc.n_pages)
    assert e_q.pc.n_pages == pages_for_budget(_probe_pc(4, "int8"), budget)


def test_equal_budget_strictly_fewer_preemptions(setup):
    """The pressure workload: a byte budget tight enough to force f32
    out-of-pages preemptions. int8 buys ~4x the pages from the same
    bytes and must preempt strictly less (the capacity claim of KV
    quantization, end to end through scheduler re-admission)."""
    tok, params = setup
    # 40 f32 pages: tight enough that f32 preempts heavily (and finishes
    # almost nothing), roomy enough that nobody is failed outright — at
    # harsher budgets f32 requests can never fit even alone, the
    # scheduler fails them, and the preemption comparison loses meaning.
    budget = 40 * _probe_pc(4, "f32").page_bytes
    prompt = "kappa iota theta eta zeta epsilon delta gamma beta alpha " * 4

    def serve(kv_dtype):
        eng = make_engine(params, tok, kv_pool_bytes=budget,
                          kv_dtype=kv_dtype, max_slots=6)
        sched = ContinuousScheduler(eng, policy="fcfs", clock="step")
        reqs = [ServeRequest(prompt=prompt, plan=DIAMOND, arrival=0.0)
                for _ in range(6)]
        return sched.run(reqs)

    rep_f = serve("f32")
    rep_q = serve("int8")
    assert rep_f.n_preemptions >= 1, "budget not tight enough to test"
    assert rep_q.n_preemptions < rep_f.n_preemptions
    assert rep_q.n_completed == 6
