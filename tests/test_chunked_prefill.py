"""Chunked prefill tests: temp-0 parity against monolithic prefill,
genuine interleaving with decode steps (another request's first token
lands before the long prompt finishes ingesting), clean rollback when
a request aborts or preempts mid-ingestion, trace-span validation
through tools/check_trace.py, and the SerialEngine guard."""

import os
import subprocess
import sys

import jax
import pytest

from repro.configs import get_config
from repro.data.tokenizer import Tokenizer
from repro.engine import EngineConfig, MedVerseEngine, SerialEngine
from repro.models import init_params
from repro.serving import ContinuousScheduler, ServeRequest

CFG = get_config("medverse-7b", smoke=True)

DIAMOND = ("<Plan> "
           "<Outline> Transient Step 1: q -> A ; Dependency: [] </Outline> "
           "<Outline> Transient Step 2: q -> B ; Dependency: [] </Outline> "
           "<Outline> Transient Step 3: A , B -> C ; Dependency: [1, 2] "
           "</Outline> </Plan>")

SERIAL = ("<Plan> "
          "<Outline> Transient Step 1: q -> A ; Dependency: [] </Outline> "
          "</Plan>")

LONG = "kappa iota theta eta zeta epsilon delta gamma beta alpha " * 6
SHORT = "alpha beta gamma q"


def make_tok():
    corpus = ["alpha beta gamma delta epsilon zeta eta theta iota kappa "
              "Transient Step 1: 2: 3: Dependency: [] [1] [2] [1, 2] "
              "A -> B ; C D q x y z"]
    return Tokenizer.train(corpus)


@pytest.fixture(scope="module")
def setup():
    tok = make_tok()
    params = init_params(jax.random.PRNGKey(0), CFG)
    return tok, params


def make_engine(params, tok, **kw):
    base = dict(max_slots=4, page_size=4, n_pages=512, max_chain_len=256,
                max_step_tokens=6, max_conclusion_tokens=6)
    base.update(kw)
    return MedVerseEngine(params, CFG, tok, EngineConfig(**base))


def _chunk_spans(eng, rid=None):
    return [ev for ev in eng.obs.events
            if ev.get("ph") == "X" and ev.get("name") == "prefill_chunk"
            and (rid is None or ev.get("rid") == rid)]


# ------------------------------------------------------------ parity ------

@pytest.mark.parametrize("backend", ["dense", "pallas"])
def test_temp0_parity_vs_monolithic(setup, backend):
    """Slicing a prompt into chunks must not change a single temp-0
    token: the last prompt row's logits are the same sample point
    monolithic prefill uses, and adaptive positions are identical."""
    tok, params = setup
    e_m = make_engine(params, tok, attention_backend=backend)
    e_c = make_engine(params, tok, attention_backend=backend,
                      prefill_chunk=3)
    r_m = e_m.generate([LONG, SHORT], plans=[DIAMOND, DIAMOND])
    r_c = e_c.generate([LONG, SHORT], plans=[DIAMOND, DIAMOND])
    assert [r.text for r in r_m] == [r.text for r in r_c]
    assert [r.step_texts for r in r_m] == [r.step_texts for r in r_c]


def test_chunk_larger_than_prompt_is_monolithic(setup):
    """Prompts at or under the chunk length take the monolithic path:
    no pending ingestion, no prefill_chunk spans."""
    tok, params = setup
    eng = make_engine(params, tok, prefill_chunk=256, trace=True)
    eng.generate([SHORT], plans=[SERIAL])
    assert not _chunk_spans(eng)


# ------------------------------------------------------- interleaving -----

def test_short_request_first_token_before_long_ingest_ends(setup):
    """The head-of-line claim, end to end: while a long prompt is still
    being ingested chunk by chunk, a short request admitted alongside
    it decodes and produces its first token. Monolithic prefill cannot
    do this — it finishes the whole prompt inside admission."""
    tok, params = setup
    eng = make_engine(params, tok, prefill_chunk=3, trace=True,
                      max_slots=6)
    rid_long = eng.add_request(LONG, plan=SERIAL)
    rid_short = eng.add_request(SHORT, plan=SERIAL)
    while eng.n_requests():
        eng.step()
    spans = _chunk_spans(eng, rid_long)
    assert len(spans) >= 2, "long prompt did not chunk"
    steps = [ev["step"] for ev in spans]
    assert steps == sorted(steps) and len(set(steps)) == len(steps)
    n_prompt = spans[0]["args"]["n_prompt"]
    n_cached = spans[0]["args"]["n_cached"]
    assert sum(ev["args"]["n_rows"] for ev in spans) == n_prompt - n_cached
    first_tok = [ev for ev in eng.obs.events
                 if ev.get("name") == "first_token"
                 and ev.get("rid") == rid_short]
    assert first_tok, "short request produced no token"
    assert first_tok[0]["step"] < steps[-1], (
        "short request's first token should land before the long "
        "prompt finished ingesting")


# ------------------------------------------------------------ rollback ----

def test_abort_mid_chunk_rolls_back(setup):
    """Aborting a request mid-ingestion frees every partially written
    page and leaves the radix tree without the prompt: a later lookup
    must not adopt a half-prefilled prefix."""
    tok, params = setup
    eng = make_engine(params, tok, prefill_chunk=3, trace=True)
    assert eng.alloc.used == 0
    rid = eng.add_request(LONG, plan=DIAMOND)
    for _ in range(3):   # ingest a few chunks, nowhere near the end
        eng.step()
    spans = _chunk_spans(eng, rid)
    assert spans, "no chunks ingested before the abort"
    n_prompt = spans[0]["args"]["n_prompt"]
    assert sum(ev["args"]["n_rows"] for ev in spans) < n_prompt
    assert eng.alloc.used > 0
    assert eng.abort(rid)
    assert eng.alloc.used == 0
    cached, path = eng.radix.match_prefix(tok.encode(LONG, bos=True))
    eng.radix.release(path)
    assert cached.size == 0, "radix indexed a half-prefilled prompt"
    st = eng.alloc.stats()
    assert st["allocs"] - st["frees"] == 0


def test_preempt_mid_chunk_recovers_under_pressure(setup):
    """Chunked prefill under page pressure: preempted requests (some
    mid-ingestion) re-queue, re-admit, and every request completes with
    the same text a pressure-free run produces."""
    tok, params = setup

    def serve(n_pages):
        eng = make_engine(params, tok, prefill_chunk=3, n_pages=n_pages,
                          max_slots=6)
        sched = ContinuousScheduler(eng, policy="fcfs", clock="step")
        reqs = [ServeRequest(prompt=LONG, plan=DIAMOND, arrival=0.0)
                for _ in range(6)]
        rep = sched.run(reqs)
        texts = [r.result.text for r in sched.finished
                 if r.result is not None]
        # used already excludes pinned-only radix pages: no live stream
        # may hold a page once the fleet drains
        assert eng.alloc.used == 0
        return rep, texts

    # 160 pages: tight enough to preempt a couple of victims (some
    # mid-ingestion), roomy enough that every re-admitted request still
    # completes — tighter pools start failing requests outright
    rep_free, texts_free = serve(512)
    rep_tight, texts_tight = serve(160)
    assert rep_tight.n_preemptions >= 1, "pressure run never preempted"
    assert rep_tight.n_completed == 6
    assert sorted(texts_tight) == sorted(texts_free)


# ------------------------------------------------------------- traces -----

def test_dumped_trace_passes_check_trace(setup, tmp_path):
    """The chunked-ingestion trace satisfies tools/check_trace.py's
    prefill_chunk span rules (dense seq, contiguous offsets, strictly
    increasing steps, rows summing to the uncached prompt length) and
    carries kv_dtype in its meta."""
    tok, params = setup
    path = str(tmp_path / "chunked_trace.jsonl")
    eng = make_engine(params, tok, prefill_chunk=3, trace=path)
    eng.generate([LONG, SHORT], plans=[SERIAL, SERIAL])
    jsonl_path, _ = eng.dump_trace()
    checker = os.path.join(os.path.dirname(__file__), "..", "tools",
                           "check_trace.py")
    proc = subprocess.run([sys.executable, checker, jsonl_path],
                          capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


# ------------------------------------------------------------- guards -----

def test_serial_engine_rejects_chunking(setup):
    tok, params = setup
    with pytest.raises(ValueError, match="prefill_chunk"):
        SerialEngine(params, CFG, tok,
                     EngineConfig(max_slots=2, prefill_chunk=4))
