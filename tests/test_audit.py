"""Clinical audit-trail tests: stage-typed plan grammar, the
deterministic rule-based verdict extractor, audit passivity (temp-0
output bit-identical with auditing on/off, on every scheduling path and
both attention backends), edge paths (preemption mid-critic, abort
before conclusion), the stage-aware critic-priority scheduler, the
verified-serving report, and the audit JSONL round-trip + validator."""

import json
import subprocess
import sys

import jax
import pytest

from repro.configs import get_config
from repro.core.dag import ReasoningDAG
from repro.core.petri import ColoredToken, PetriNet, PetriScheduler
from repro.core.plan import DEFAULT_STAGE, PlanParseError, parse_plan
from repro.data.tokenizer import Tokenizer
from repro.engine import EngineConfig, MedVerseEngine
from repro.models import init_params
from repro.obs import (AUDIT_SCHEMA, AuditTrail, load_audit_jsonl,
                       request_timelines, rule_verdict, summarize,
                       validate_spans)
from repro.serving import ContinuousScheduler, ServeRequest

CFG = get_config("medverse-7b", smoke=True)

# 5-step staged plan: the critic (step 2) gates two sibling branches
# (steps 3 and 4 both depend on it — unblock count 2), the guardrail
# (step 5) joins them. Spaced punctuation per the word-level tokenizer.
STAGED = (
    "<Plan> "
    "<Outline> Transient Step 1: q -> A ; Dependency: [ ] </Outline> "
    "<Outline> Transient Step 2: verify A ; Dependency: [ 1 ] ; "
    "Stage: critic </Outline> "
    "<Outline> Transient Step 3: A -> B ; Dependency: [ 2 ] </Outline> "
    "<Outline> Transient Step 4: A -> C ; Dependency: [ 2 ] </Outline> "
    "<Outline> Transient Step 5: safety screen ; Dependency: [ 3 , 4 ] ; "
    "Stage: guardrail </Outline> "
    "</Plan>")

REASON_ONLY = (
    "<Plan> "
    "<Outline> Transient Step 1: q -> A ; Dependency: [ ] </Outline> "
    "<Outline> Transient Step 2: q -> B ; Dependency: [ ] </Outline> "
    "<Outline> Transient Step 3: A , B -> C ; Dependency: [ 1 , 2 ] "
    "</Outline> </Plan>")


def make_tok():
    corpus = ["alpha beta gamma delta epsilon zeta eta theta iota kappa "
              "Transient Step 1: 2: 3: 4: 5: 6: 7: 8: 1 2 3 4 5 , [ ] "
              "Dependency: [] [1] [2] [1, 2] "
              "Stage: critic guardrail verify safety screen "
              "A -> B ; C D q x y z"]
    return Tokenizer.train(corpus)


@pytest.fixture(scope="module")
def setup():
    tok = make_tok()
    params = init_params(jax.random.PRNGKey(0), CFG)
    return tok, params


def make_engine(params, tok, **kw):
    base = dict(max_slots=4, page_size=4, n_pages=512, max_chain_len=256,
                max_step_tokens=6, max_conclusion_tokens=6)
    base.update(kw)
    return MedVerseEngine(params, CFG, tok, EngineConfig(**base))


# ------------------------------------------------- stage grammar units -----
def test_stage_parse_and_default():
    plan = parse_plan(STAGED)
    assert [s.stage for s in plan.steps] == [
        "reason", "critic", "reason", "reason", "guardrail"]
    legacy = parse_plan(REASON_ONLY)
    assert all(s.stage == DEFAULT_STAGE for s in legacy.steps)


def test_stage_serialize_round_trip():
    plan = parse_plan(STAGED)
    again = parse_plan(plan.serialize())
    assert [(s.index, s.stage, s.dependencies) for s in again.steps] == \
        [(s.index, s.stage, s.dependencies) for s in plan.steps]
    # default-stage steps serialize without a Stage clause: the legacy
    # grammar is emitted unchanged for legacy plans
    assert "Stage:" not in parse_plan(REASON_ONLY).serialize()


def test_unknown_stage_strict_vs_lenient():
    bad = STAGED.replace("Stage: critic", "Stage: judge")
    with pytest.raises(PlanParseError):
        parse_plan(bad)             # strict: closed vocabulary
    plan = parse_plan(bad, lenient=True)   # engine-side: degrade
    assert plan.steps[1].stage == "reason"


def test_unk_stage_degrades_to_reason():
    """A staged plan decoded through a stale tokenizer turns the stage
    clause into <unk> tokens; the outline must survive with the default
    stage rather than being dropped."""
    for mangled in (STAGED.replace("Stage: critic", "Stage: <unk>"),
                    STAGED.replace("Stage: critic", "<unk> <unk>")):
        plan = parse_plan(mangled, lenient=True)
        assert len(plan.steps) == 5
        assert plan.steps[1].stage == "reason"


def test_dag_stages_sparse_and_backward_compatible():
    plan = parse_plan(STAGED)
    dag = plan.to_dag()
    assert dag.stage_of(1) == "critic"
    assert dag.stage_of(0) == "reason"
    assert 0 not in dag.stages       # default stages are not stored...
    legacy = parse_plan(REASON_ONLY).to_dag()
    # ...so an all-reason DAG equals its stage-free construction
    assert legacy == ReasoningDAG.from_deps(
        {0: (), 1: (), 2: (0, 1)})


def test_petri_stage_and_unblock_count():
    dag = parse_plan(STAGED).to_dag()
    net = PetriNet.from_dag(dag)
    by_tid = {t.tid: t for t in net.transitions}
    assert by_tid[1].stage == "critic"
    sched = PetriScheduler(net, ColoredToken(history="ctx"))
    sched.fire(by_tid[0], ColoredToken(history="h0"))
    # firing the critic enables both siblings (steps 3 and 4)
    assert sched.unblock_count(by_tid[1]) == 2
    assert sched.unblock_count(by_tid[4]) == 0


# --------------------------------------------- verdict extractor units -----
def test_rule_verdict_markers_last_wins():
    v = rule_verdict("finding looks inconsistent but ultimately "
                     "confirmed against labs")
    assert v.status == "pass" and "confirmed" in v.reason
    assert v.span[0] >= 0
    v = rule_verdict("initially plausible yet finally contraindicated")
    assert v.status == "fail" and v.evidence == "contraindicated"


def test_rule_verdict_evidence_overlap():
    ev = "elevated troponin suggests cardiac injury"
    assert rule_verdict("troponin elevated matches cardiac marker",
                        ev).status == "pass"
    # substantive body, zero shared content words: ungrounded critique
    assert rule_verdict("glucose ferritin albumin bilirubin",
                        ev).status == "fail"
    # too short to decide anything
    assert rule_verdict("brief note", ev).status == "abstain"


def test_rule_verdict_deterministic():
    body, ev = "troponin elevated matches cardiac marker", "troponin cardiac"
    assert rule_verdict(body, ev) == rule_verdict(body, ev)


# -------------------------------------------------- trail unit + jsonl -----
def test_audit_trail_dispositions():
    trail = AuditTrail()
    # verified: critic passes, guardrail clean
    trail.on_stream_end(0, 0, "reason", "q alpha", "", step=1)
    trail.on_stream_end(0, 1, "critic", "finding confirmed correct", "",
                        step=2)
    trail.on_stream_end(0, 2, "guardrail", "dose safe", "", step=3)
    rep = trail.finish_request(0, completed=True, step=4).report
    assert rep.disposition == "verified" and rep.critic_coverage == 1.0
    # refuted: guardrail violation
    trail.on_stream_end(1, 0, "critic", "finding confirmed correct", "",
                        step=5)
    trail.on_stream_end(1, 1, "guardrail",
                        "combination contraindicated here", "", step=6)
    rep = trail.finish_request(1, completed=True, step=7).report
    assert rep.disposition == "refuted"
    assert rep.guardrail_violations == 1
    # unverified: no critics at all
    trail.on_stream_end(2, 0, "reason", "q beta", "", step=8)
    rep = trail.finish_request(2, completed=True, step=9).report
    assert rep.disposition == "unverified"
    # unverified: abort before conclusion
    trail.on_stream_end(3, 0, "critic", "finding confirmed correct", "",
                        step=10)
    rep = trail.finish_request(3, completed=False, step=11).report
    assert rep.disposition == "unverified" and rep.completed is False


def test_audit_preempt_drops_partial_decisions():
    trail = AuditTrail()
    trail.on_stream_end(0, 1, "critic", "finding confirmed", "", step=2)
    trail.on_preempt(0)
    assert trail.records == []       # deferred to the re-run
    # re-admission re-decodes and re-records; exactly one decision and
    # one disposition survive
    trail.on_stream_end(0, 1, "critic", "finding confirmed", "", step=9)
    trail.finish_request(0, completed=True, step=10)
    kinds = [r.kind for r in trail.records]
    assert kinds == ["decision", "disposition"]


def test_audit_jsonl_round_trip(tmp_path):
    trail = AuditTrail(meta={"model": "t"})
    trail.on_stream_end(0, 1, "critic", "finding confirmed correct", "",
                        step=2, track="t1")
    trail.finish_request(0, completed=True, step=3)
    path = trail.dump_jsonl(str(tmp_path / "audit.jsonl"))
    header, records = load_audit_jsonl(path)
    assert header["schema"] == AUDIT_SCHEMA
    assert header["meta"] == {"model": "t"}
    assert [r.to_dict() for r in records] == \
        [r.to_dict() for r in trail.records]
    with pytest.raises(ValueError):
        bad = tmp_path / "bad.jsonl"
        bad.write_text(json.dumps({"schema": "other/1"}) + "\n")
        load_audit_jsonl(str(bad))


# ------------------------------------------------- engine integration ------
PARITY_CASES = [
    ("dense", {}),
    ("dense", {"async_frontier": True}),
    ("dense", {"speculative": True}),
    ("dense", {"n_pages": 48}),             # tight pool forces preemption
    ("pallas", {}),
]


@pytest.mark.parametrize(
    "backend,variant", PARITY_CASES,
    ids=["dense", "async", "spec", "preempt", "pallas"])
def test_temp0_parity_audit_on_off(setup, backend, variant):
    """Auditing is passive on every scheduling path (sync, async,
    speculative, preemption) under both attention backends: temp-0
    output text and decode-iteration counts are bit-identical with the
    audit trail on or off."""
    tok, params = setup
    kw = dict(plan_override=STAGED, attention_backend=backend,
              kernel_interpret=True, **variant)
    prompts = ["q alpha beta", "q beta gamma"]
    off = make_engine(params, tok, **kw)
    r_off = off.generate(prompts)
    on = make_engine(params, tok, audit=True, **kw)
    r_on = on.generate(prompts)
    assert [r.text for r in r_on] == [r.text for r in r_off]
    assert [r.step_texts for r in r_on] == [r.step_texts for r in r_off]
    assert on.total_iters == off.total_iters
    assert len(on.audit.records) > 0       # ...while actually auditing
    # every request closed with exactly one disposition, and no stream
    # produced a duplicate decision (preemption defers, never doubles)
    per_rid = {}
    seen = set()
    for r in on.audit.records:
        if r.kind == "disposition":
            per_rid[r.rid] = per_rid.get(r.rid, 0) + 1
        else:
            assert (r.rid, r.node) not in seen
            seen.add((r.rid, r.node))
    assert per_rid == {0: 1, 1: 1}
    if variant.get("n_pages") == 48:
        assert on.preemptions > 0          # the path actually exercised


def test_spec_decoding_bit_identical_verdicts(setup):
    """Speculative decoding commits the same temp-0 text, so the audit
    trail's verdicts are bit-identical with the drafter on or off."""
    tok, params = setup
    base = dict(plan_override=STAGED, audit=True)
    plain = make_engine(params, tok, **base)
    plain.generate(["q alpha beta", "q beta gamma"])
    spec = make_engine(params, tok, speculative=True, **base)
    spec.generate(["q alpha beta", "q beta gamma"])

    def sig(eng):
        return [(r.rid, r.node, r.stage, r.verdict.status,
                 r.verdict.reason) if r.kind == "decision"
                else (r.rid, r.disposition)
                for r in eng.audit.records]

    assert sig(spec) == sig(plain)


def test_abort_yields_unverified_and_balanced_spans(setup):
    tok, params = setup
    eng = make_engine(params, tok, plan_override=STAGED, audit=True,
                      trace=True)
    rid = eng.add_request("q alpha beta")
    for _ in range(3):
        eng.step()
    eng.abort(rid)
    rep = eng.audit.reports[rid]
    assert rep.disposition == "unverified" and rep.completed is False
    assert validate_spans(eng.obs.events) == []


def test_critic_priority_fires_on_gate_plan(setup):
    """A ready critic whose verdict unblocks >= 2 sibling branches is
    prioritized, and the decision is visible in the trace; all-reason
    plans never trigger it (legacy schedule untouched)."""
    tok, params = setup
    eng = make_engine(params, tok, plan_override=STAGED, trace=True)
    eng.generate(["q alpha beta"])
    prios = [ev for ev in eng.obs.events
             if ev["name"] == "critic_priority"]
    assert prios and all(ev["args"]["unblocks"] >= 2 for ev in prios)

    legacy = make_engine(params, tok, plan_override=REASON_ONLY,
                         trace=True)
    legacy.generate(["q alpha beta"])
    assert not [ev for ev in legacy.obs.events
                if ev["name"] == "critic_priority"]


def test_metrics_registry_exposes_audit_counters(setup):
    tok, params = setup
    eng = make_engine(params, tok, plan_override=STAGED, audit=True)
    eng.generate(["q alpha beta"])
    text = eng.metrics_registry().to_prom_text()
    assert "medverse_audit_records_total" in text
    assert "medverse_audit_verdict_abstain_total" in text
    assert "medverse_audit_disposition_unverified_total" in text


# -------------------------------------------- timeline + serving layer -----
def test_timeline_stage_and_verdict_annotations(setup):
    tok, params = setup
    eng = make_engine(params, tok, plan_override=STAGED, audit=True,
                      trace=True)
    eng.generate(["q alpha beta"])
    tls = request_timelines(eng.obs.events)
    tl = tls[0]
    assert tl.disposition in ("verified", "refuted", "unverified")
    by_track = {s.track: s for s in tl.streams}
    assert by_track["t2"].stage == "critic"
    assert by_track["t2"].verdict in ("pass", "fail", "abstain")
    assert by_track["t1"].stage == "reason" and not by_track["t1"].verdict
    text = summarize(eng.obs.events, tls)
    assert "[critic" in text and "verified=" in text


def test_serving_report_verified_block(setup):
    tok, params = setup
    eng = make_engine(params, tok, plan_override=STAGED, audit=True)
    sched = ContinuousScheduler(eng, clock="step")
    seen = []
    rep = sched.run([
        ServeRequest(prompt="q alpha beta", plan=STAGED, arrival=0.0,
                     on_audit=lambda rid, rec: seen.append(rec.kind)),
        ServeRequest(prompt="q beta gamma", plan=STAGED, arrival=3.0)])
    assert sum(rep.dispositions.values()) == 2
    assert sum(rep.verdicts.values()) == 4      # 2 x (critic + guardrail)
    assert set(rep.stage_ttft_steps) == {"reason", "critic", "guardrail"}
    assert "critic" in rep.stage_tpot_steps
    assert rep.n_verified == rep.dispositions.get("verified", 0)
    assert "verified=" in rep.summary()
    assert "decision" in seen and "disposition" in seen
    d = rep.to_dict()
    assert "verified_goodput" in d and "verified_per_step" in d


def test_serving_report_without_audit_unchanged(setup):
    tok, params = setup
    eng = make_engine(params, tok, plan_override=STAGED)
    rep = ContinuousScheduler(eng, clock="step").run(
        [ServeRequest(prompt="q alpha beta", plan=STAGED, arrival=0.0)])
    assert rep.dispositions == {} and rep.verdicts == {}
    assert "verified=" not in rep.summary()


# -------------------------------------------------- validator coverage -----
def test_check_trace_accepts_audited_artifacts(setup, tmp_path):
    tok, params = setup
    eng = make_engine(params, tok, plan_override=STAGED, audit=True,
                      trace=True)
    eng.generate(["q alpha beta", "q beta gamma"])
    trace = str(tmp_path / "trace.jsonl")
    audit = str(tmp_path / "audit.jsonl")
    eng.dump_trace(trace)
    eng.dump_audit(audit)
    proc = subprocess.run(
        [sys.executable, "tools/check_trace.py", trace, audit],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_check_trace_rejects_bad_audit(setup, tmp_path):
    tok, params = setup
    eng = make_engine(params, tok, plan_override=STAGED, audit=True)
    eng.generate(["q alpha beta"])
    audit = str(tmp_path / "audit.jsonl")
    eng.dump_audit(audit)
    lines = open(audit).read().splitlines()
    doc = [json.loads(ln) for ln in lines]
    # corrupt a verdict and drop the disposition
    for d in doc[1:]:
        if d.get("kind") == "decision":
            d["verdict"]["status"] = "maybe"
    doc = [d for d in doc if d.get("kind") != "disposition"]
    with open(audit, "w") as f:
        f.write("\n".join(json.dumps(d) for d in doc) + "\n")
    proc = subprocess.run(
        [sys.executable, "tools/check_trace.py", audit],
        capture_output=True, text=True)
    assert proc.returncode == 1
    assert "status" in proc.stdout and "disposition" in proc.stdout
