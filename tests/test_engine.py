"""Engine tests: paged decode correctness vs the dense model path,
zero-copy fork/join semantics, radix tree, allocator refcounts, and the
full two-phase generate() flow."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.plan import parse_plan
from repro.data.tokenizer import SPECIALS, Tokenizer
from repro.engine import (
    EngineConfig,
    IndexChain,
    MedVerseEngine,
    PageAllocator,
    PoolConfig,
    RadixTree,
    SerialEngine,
    init_pool,
    paged_decode,
    prefill_forward,
)
from repro.models import TopoBatch, forward, init_params


CFG = get_config("medverse-7b", smoke=True)


def make_tok():
    corpus = ["alpha beta gamma delta epsilon zeta eta theta iota kappa "
              "Transient Step 1: 2: 3: Dependency: [] [1] [2] [1, 2] "
              "A -> B ; C D q x y z"]
    return Tokenizer.train(corpus)


@pytest.fixture(scope="module")
def setup():
    tok = make_tok()
    params = init_params(jax.random.PRNGKey(0), CFG)
    return tok, params


def test_prefill_matches_forward(setup):
    tok, params = setup
    ids = np.arange(1, 11, dtype=np.int32)
    logits, ks, vs = prefill_forward(
        params, jnp.asarray(ids)[None], jnp.arange(10, dtype=jnp.int32)[None],
        CFG)
    full, _ = forward(params, jnp.asarray(ids)[None],
                      TopoBatch.linear(1, 10), CFG)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[0, -1]),
                               rtol=2e-4, atol=2e-4)
    assert ks.shape == (CFG.n_layers, 10, CFG.n_kv_heads, CFG.resolved_head_dim)


def test_paged_decode_matches_dense(setup):
    """Linear paged decode logits == teacher-forced forward logits."""
    tok, params = setup
    seq = np.asarray([5, 9, 3, 7, 2, 8, 4, 6], np.int32)
    full, _ = forward(params, jnp.asarray(seq)[None],
                      TopoBatch.linear(1, len(seq)), CFG)

    pc = PoolConfig(n_layers=CFG.n_layers, n_pages=64, page_size=4,
                    n_kv_heads=CFG.n_kv_heads,
                    head_dim=CFG.resolved_head_dim)
    pool = init_pool(pc)
    alloc = PageAllocator(pc)
    chain = IndexChain.fresh(alloc)
    n_slots_batch = 2
    s_max = 32
    for i, t in enumerate(seq):
        slot = chain.next_slot()
        tokens = jnp.asarray(np.pad([t], (0, n_slots_batch - 1)))
        qp = jnp.asarray(np.pad([i], (0, n_slots_batch - 1)))
        sl = jnp.asarray(np.pad([slot], (0, n_slots_batch - 1)))
        ci = jnp.asarray(np.pad(chain.padded(s_max)[None],
                                [(0, n_slots_batch - 1), (0, 0)]))
        cl = jnp.asarray(np.pad([chain.length], (0, n_slots_batch - 1)))
        logits, pool["k"], pool["v"], pool["pos"], _, _ = paged_decode(
            params, pool["k"], pool["v"], pool["pos"], None, None,
            tokens, qp, sl, ci, cl, CFG)
        np.testing.assert_allclose(
            np.asarray(logits[0]), np.asarray(full[0, i]),
            rtol=3e-4, atol=3e-4,
            err_msg=f"paged decode diverges at position {i}")


def test_fork_zero_copy_and_refcounts():
    pc = PoolConfig(n_layers=1, n_pages=8, page_size=4, n_kv_heads=1,
                    head_dim=8)
    alloc = PageAllocator(pc)
    parent = IndexChain.fresh(alloc)
    parent.reserve(6)  # 2 pages
    assert alloc.pages_in_use == 2
    child = parent.fork()
    # zero-copy: same slot indices, no new pages yet
    assert np.array_equal(child.idx, parent.idx)
    assert alloc.pages_in_use == 2
    # child appends into its OWN page; parent's pages untouched
    s = child.next_slot()
    assert alloc.pages_in_use == 3
    assert s // pc.page_size not in {i // pc.page_size for i in parent.idx}
    # releasing parent keeps shared pages alive for child
    parent.release()
    assert alloc.pages_in_use == 3
    child.release()
    assert alloc.pages_in_use == 0


def test_join_dedups_shared_ancestors():
    pc = PoolConfig(n_layers=1, n_pages=16, page_size=4, n_kv_heads=1,
                    head_dim=8)
    alloc = PageAllocator(pc)
    ctx = IndexChain.fresh(alloc)
    ctx.reserve(5)
    a = ctx.fork(); a.reserve(3)
    b = ctx.fork(); b.reserve(2)
    merged = IndexChain.join([a, b], prefix_len=5)
    # prefix once + suffixes
    assert merged.length == 5 + 3 + 2
    assert len(set(merged.idx.tolist())) == merged.length  # no dup slots
    # order: prefix, a-suffix, b-suffix
    assert np.array_equal(merged.idx[:5], ctx.idx[:5])
    assert np.array_equal(merged.idx[5:8], a.idx[5:8])


def test_radix_tree_prefix_reuse():
    tree = RadixTree()
    toks = [4, 5, 6, 7, 8]
    slots = np.arange(100, 105, dtype=np.int32)
    tree.insert(toks, slots)
    m, path = tree.match_prefix([4, 5, 6, 9])
    assert m.tolist() == [100, 101, 102]
    tree.release(path)
    m2, path2 = tree.match_prefix([1, 2])
    assert m2.size == 0
    # insert splits edges correctly
    tree.insert([4, 5, 9], np.asarray([100, 101, 200], np.int32))
    m3, _ = tree.match_prefix([4, 5, 9])
    assert m3.tolist() == [100, 101, 200]
    assert tree.n_cached_tokens() >= 6


PLAN = ("<Think> 1. q -> A -> C. 2. q -> B -> C. </Think> <Plan> "
        "<Outline> Transient Step 1: q -> A ; Dependency: [] </Outline> "
        "<Outline> Transient Step 2: q -> B ; Dependency: [] </Outline> "
        "<Outline> Transient Step 3: A , B -> C ; Dependency: [1, 2] "
        "</Outline> </Plan>")


def test_engine_full_flow(setup):
    """Two-phase generate() with an injected diamond plan: three steps
    decode (two in parallel), join merges, conclusion runs, and the
    critical path is shorter than total tokens."""
    tok, params = setup
    ecfg = EngineConfig(max_slots=4, page_size=4, n_pages=512,
                        max_chain_len=256, max_step_tokens=6,
                        max_conclusion_tokens=6, plan_override=PLAN)
    eng = MedVerseEngine(params, CFG, tok, ecfg)
    res = eng.generate(["q alpha beta"])[0]
    assert res.plan_ok, res.text
    assert len(res.step_texts) == 3
    assert res.topology == "complex_intersecting"
    # parallel speedup structurally: critical path < total generated
    assert res.critical_path_tokens < res.n_tokens
    assert "<Step>" in res.text and "<Conclusion>" in res.text
    # frontier layering recorded: [1,2] then [3]
    # (scheduler history holds 0-based tids)


def test_engine_fallback_on_bad_plan(setup):
    tok, params = setup
    ecfg = EngineConfig(max_slots=2, page_size=4, n_pages=256,
                        max_chain_len=128, max_plan_tokens=8,
                        max_conclusion_tokens=4)
    eng = MedVerseEngine(params, CFG, tok, ecfg)
    res = eng.generate(["alpha beta gamma"])[0]
    assert not res.plan_ok        # random model produced no valid plan
    assert res.ok                 # but the request still completes


def test_engine_batched_requests(setup):
    tok, params = setup
    ecfg = EngineConfig(max_slots=6, page_size=4, n_pages=1024,
                        max_chain_len=256, max_step_tokens=4,
                        max_conclusion_tokens=4, plan_override=PLAN)
    eng = MedVerseEngine(params, CFG, tok, ecfg)
    res = eng.generate(["q alpha", "q beta", "q gamma"])
    assert len(res) == 3
    assert all(r.plan_ok for r in res)


def test_serial_engine(setup):
    tok, params = setup
    ecfg = EngineConfig(max_slots=2, page_size=4, n_pages=256,
                        max_chain_len=128)
    eng = SerialEngine(params, CFG, tok, ecfg)
    res = eng.generate(["alpha beta"], max_tokens=8)[0]
    assert res.n_tokens == 8
