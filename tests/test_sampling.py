"""Sampling unit tests: top-k / top-p filtering against a numpy
reference, SamplingParams validation, and per-request RNG
reproducibility (output independent of batch composition)."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokenizer import Tokenizer
from repro.engine import EngineConfig, MedVerseEngine, SamplingParams
from repro.engine.sampling import sample_token, top_k_filter, top_p_filter
from repro.models import init_params

CFG = get_config("medverse-7b", smoke=True)

DIAMOND = ("<Plan> "
           "<Outline> Transient Step 1: q -> A ; Dependency: [] </Outline> "
           "<Outline> Transient Step 2: q -> B ; Dependency: [] </Outline> "
           "<Outline> Transient Step 3: A , B -> C ; Dependency: [1, 2] "
           "</Outline> </Plan>")


def make_tok():
    corpus = ["alpha beta gamma delta epsilon zeta eta theta iota kappa "
              "Transient Step 1: 2: 3: Dependency: [] [1] [2] [1, 2] "
              "A -> B ; C D q x y z"]
    return Tokenizer.train(corpus)


@pytest.fixture(scope="module")
def setup():
    tok = make_tok()
    params = init_params(jax.random.PRNGKey(0), CFG)
    return tok, params


def make_engine(params, tok, **kw):
    base = dict(max_slots=4, page_size=4, n_pages=512, max_chain_len=256,
                max_step_tokens=6, max_conclusion_tokens=6)
    base.update(kw)
    return MedVerseEngine(params, CFG, tok, EngineConfig(**base))


# ------------------------------------------------------ filter math --------
def _softmax(z):
    z = np.asarray(z, np.float64)
    z = z - z.max()
    p = np.exp(z)
    return p / p.sum()


def test_top_k_keeps_k_highest():
    rng = np.random.default_rng(0)
    logits = rng.normal(size=50)
    for k in (1, 5, 17):
        out = top_k_filter(logits, k)
        kept = np.isfinite(out)
        # reference: the k largest logits survive, all others are -inf
        ref_idx = np.argsort(logits)[-k:]
        assert kept.sum() == k
        assert set(np.where(kept)[0]) == set(ref_idx)
        assert np.array_equal(out[kept], logits[kept])


def test_top_k_disabled_and_oversized():
    logits = np.asarray([1.0, 2.0, 3.0])
    assert np.array_equal(top_k_filter(logits, 0), logits)
    assert np.array_equal(top_k_filter(logits, 10), logits)


def test_top_p_nucleus_mass():
    rng = np.random.default_rng(1)
    logits = rng.normal(size=40) * 3
    for p in (0.1, 0.5, 0.9):
        out = top_p_filter(logits, p)
        kept = np.isfinite(out)
        probs = _softmax(logits)
        # reference: smallest descending-prob prefix reaching mass p
        order = np.argsort(probs)[::-1]
        cum = np.cumsum(probs[order])
        n_keep = int(np.searchsorted(cum, p) + 1)
        assert set(np.where(kept)[0]) == set(order[:n_keep])
        # the kept set's mass reaches p; dropping its last member wouldn't
        assert probs[kept].sum() >= p - 1e-12
        if n_keep > 1:
            assert probs[order[: n_keep - 1]].sum() < p


def test_top_p_always_keeps_argmax():
    logits = np.asarray([0.0, 10.0, 0.0])
    out = top_p_filter(logits, 1e-9)
    assert np.isfinite(out[1]) and not np.isfinite(out[0])


def test_sample_token_greedy_and_filters():
    rng = np.random.default_rng(2)
    logits = np.asarray([0.1, 3.0, 1.0, 2.0])
    assert sample_token(logits, 0.0, rng) == 1          # greedy
    # top_k=1 at any temperature collapses to argmax
    for _ in range(10):
        assert sample_token(logits, 5.0, rng, top_k=1) == 1
    # tiny nucleus likewise
    for _ in range(10):
        assert sample_token(logits, 5.0, rng, top_p=1e-9) == 1
    # filters restrict support: top_k=2 only ever yields the top two
    draws = {sample_token(logits, 2.0, rng, top_k=2) for _ in range(200)}
    assert draws <= {1, 3}


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
    SamplingParams(temperature=0.7, top_k=5, top_p=0.9)  # valid


# ------------------------------------------- per-request reproducibility ---
def test_sampled_output_independent_of_batch_composition(setup):
    """Each request draws from its own Generator seeded (engine_seed,
    rid): a temperature>0 request produces identical text whether it
    shares the batch with other requests or runs alone under the same
    rid — the property continuous batching needs."""
    tok, params = setup
    sp = SamplingParams(temperature=0.8, top_k=8)
    prompt = "q alpha beta"
    eng_batch = make_engine(params, tok, plan_override=DIAMOND)
    r_batch = eng_batch.generate(
        ["q gamma delta", prompt], samplings=[sp, sp])[1]   # rid 1
    eng_solo = make_engine(params, tok, plan_override=DIAMOND)
    eng_solo.add_request(prompt, sampling=sp, rid=1)        # same rid
    solo_result = None
    while eng_solo.n_requests():
        for ev in eng_solo.step():
            if ev.kind == "done":
                solo_result = ev.result
    assert solo_result is not None
    assert solo_result.text == r_batch.text
    assert solo_result.step_texts == r_batch.step_texts


def test_sampled_output_differs_across_rids(setup):
    """Different rids seed different generators: identical prompts in
    one batch do not produce lock-step samples."""
    tok, params = setup
    sp = SamplingParams(temperature=1.2)
    eng = make_engine(params, tok, plan_override=DIAMOND)
    ra, rb = eng.generate(["q alpha beta", "q alpha beta"],
                          samplings=[sp, sp])
    assert ra.text != rb.text
