"""Unit + property tests for the DAG / Petri-net / plan core."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    ColoredToken,
    CycleError,
    PetriNet,
    PetriScheduler,
    PlanParseError,
    ReasoningDAG,
    ReasoningPlan,
    OutlineStep,
    merge_paths_to_dag,
    parse_answer,
    parse_plan,
    parse_steps,
)


# ---------------------------------------------------------------- DAG ----
def diamond():
    # 0 -> 1, 0 -> 2, {1,2} -> 3
    return ReasoningDAG.from_deps({0: [], 1: [0], 2: [0], 3: [1, 2]})


def test_layers_diamond():
    assert diamond().topological_layers() == [[0], [1, 2], [3]]
    assert diamond().depth() == 3


def test_cycle_detected():
    with pytest.raises(CycleError):
        ReasoningDAG.from_deps({0: [1], 1: [0]})


def test_self_loop_detected():
    with pytest.raises(CycleError):
        ReasoningDAG.from_deps({0: [0]})


def test_unknown_dep():
    with pytest.raises(ValueError):
        ReasoningDAG.from_deps({0: [5]})


def test_ancestors():
    d = diamond()
    assert d.ancestors(3) == frozenset({0, 1, 2})
    assert d.ancestors(0) == frozenset()


def test_classify():
    assert ReasoningDAG.from_deps({0: [], 1: [0]}).classify_topology() == (
        "single_linear_chain"
    )
    # two independent chains joining only at a final conclusion-like sink
    two = ReasoningDAG.from_deps({0: [], 1: [], 2: [0], 3: [1]})
    assert two.classify_topology() == "multiple_independent_chains"
    assert diamond().classify_topology() == "complex_intersecting"


@st.composite
def random_dag_deps(draw):
    n = draw(st.integers(min_value=1, max_value=12))
    deps = {}
    for v in range(n):
        if v == 0:
            deps[v] = []
        else:
            k = draw(st.integers(min_value=0, max_value=min(3, v)))
            deps[v] = sorted(
                draw(
                    st.lists(
                        st.integers(min_value=0, max_value=v - 1),
                        min_size=k,
                        max_size=k,
                        unique=True,
                    )
                )
            )
    return deps


@settings(max_examples=60, deadline=None)
@given(random_dag_deps())
def test_property_layers_respect_deps(deps):
    """Every node sits in a strictly later layer than all its deps, and the
    layering partitions the node set."""
    dag = ReasoningDAG.from_deps(deps)
    layers = dag.topological_layers()
    where = {v: i for i, layer in enumerate(layers) for v in layer}
    assert sorted(where) == sorted(dag.nodes)
    for v in dag.nodes:
        for p in dag.predecessors(v):
            assert where[p] < where[v]


@settings(max_examples=60, deadline=None)
@given(random_dag_deps())
def test_property_petri_run_matches_layers(deps):
    """Max-parallel Petri execution fires exactly the topological layers,
    each transition exactly once (the 'fires exactly once' invariant)."""
    dag = ReasoningDAG.from_deps(deps)
    net = PetriNet.from_dag(dag)
    sched = PetriScheduler(net, ColoredToken(history="ctx"))
    fired_order = []

    def execute(t, inputs):
        fired_order.append(t.tid)
        return ColoredToken(history="+".join(i.history for i in inputs) + f"|{t.tid}")

    sched.run(execute)
    assert sched.is_complete()
    assert sorted(fired_order) == sorted(dag.nodes)
    assert sched.frontier_layers() == dag.topological_layers()


# ---------------------------------------------------------------- Petri ---
def test_fork_join_modes():
    net = PetriNet.from_dag(diamond())
    sched = PetriScheduler(net, ColoredToken(history="ctx"))
    rounds = sched.run(lambda t, inputs: ColoredToken(history=f"h{t.tid}"))
    modes = {f.transition.tid: f.mode for rnd in rounds for f in rnd}
    assert modes[1] == "fork" and modes[2] == "fork"  # share place of 0
    assert modes[3] == "join"


def test_token_history_flows():
    net = PetriNet.from_dag(diamond())
    sched = PetriScheduler(net, ColoredToken(history="ctx"))

    def execute(t, inputs):
        return ColoredToken(history=",".join(i.history for i in inputs) + f">{t.tid}")

    sched.run(execute)
    final = sched.marking.get(net.transition(3).post[0])
    assert "1" in final.history and "2" in final.history


# ---------------------------------------------------------------- Plan ----
EXAMPLE_PLAN = (
    "some linear thinking... <Plan> "
    "<Outline> Transient Step 1: Thyrotoxicosis -> KI; Dependency: [] </Outline> "
    "<Outline> Transient Step 2: Thyrotoxicosis -> Iodine; Dependency: [] </Outline> "
    "<Outline> Transient Step 3: KI, Iodine -> Reduced vascularity; "
    "Dependency: [1, 2] </Outline> </Plan> trailing"
)


def test_parse_plan_roundtrip():
    plan = parse_plan(EXAMPLE_PLAN)
    assert len(plan.steps) == 3
    assert plan.steps[2].dependencies == (1, 2)
    dag = plan.to_dag()
    assert dag.topological_layers() == [[0, 1], [2]]
    reparsed = parse_plan(plan.serialize())
    assert reparsed == plan


def test_parse_plan_missing_dep():
    bad = ReasoningPlan(
        steps=(OutlineStep(index=1, label="A -> B", dependencies=(7,)),)
    )
    with pytest.raises(PlanParseError):
        bad.to_dag()


def test_parse_plan_rejects_garbage():
    with pytest.raises(PlanParseError):
        parse_plan("no plan here")
    with pytest.raises(PlanParseError):
        parse_plan("<Plan> empty </Plan>")


def test_parse_steps_and_answer():
    text = (
        "<Step> Transient Step 1: A -> B because of X. </Step>"
        "<Step> Transient Step 2: B -> C hence Y. </Step>"
        "<Conclusion> Explanation: as shown. Answer: b) Obv </Conclusion>"
    )
    steps = parse_steps(text)
    assert set(steps) == {1, 2}
    assert "because of X" in steps[1]
    assert parse_answer(text) == "b) Obv"


def test_merge_paths_to_dag():
    paths = [["q", "A", "C"], ["q", "B", "C"]]
    dag, meta = merge_paths_to_dag(paths)
    # transitions: ->A, ->B, {A,B}->C
    layers = dag.topological_layers()
    assert len(layers) == 2 and len(layers[0]) == 2
    targets = {meta[t][0] for t in dag.nodes}
    assert targets == {"A", "B", "C"}
