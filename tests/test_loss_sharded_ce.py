"""The sharded-CE (one-hot contraction) path must be numerically
identical to the take_along_axis gather path (§Perf iteration)."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.train import loss as loss_mod


def test_onehot_ce_equals_gather_ce(monkeypatch):
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (2, 8, 32))
    targets = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 32)
    mask = (jax.random.uniform(jax.random.PRNGKey(2), (2, 8)) > 0.3
            ).astype(jnp.float32)
    monkeypatch.setattr(loss_mod, "_SHARDED_CE", False)
    a = float(loss_mod.masked_ce(logits, targets, mask))
    monkeypatch.setattr(loss_mod, "_SHARDED_CE", True)
    b = float(loss_mod.masked_ce(logits, targets, mask))
    np.testing.assert_allclose(a, b, rtol=1e-6)
