"""Analytic cost accounting tests: geometry/ledger formula units, the
engine-integration contract — cost accounting is *passive* (temp-0
output and step counts bit-identical on/off), its totals close exactly
against an independent reconstruction from the bucket histogram, the
CompileWatcher enforces the bucket-ladder invariant (zero recompiles
after a full warmup, a detected recompile after a partial one) — plus
the live ``/metrics`` endpoint (scrape, parse, assert cost counters)
and the offline ``tools/trace_view.py`` renderer."""

import subprocess
import sys
import urllib.error
import urllib.request

import jax
import pytest

from repro.configs import get_config
from repro.data.tokenizer import Tokenizer
from repro.engine import EngineConfig, MedVerseEngine
from repro.models import init_params
from repro.obs import (COST_FIELDS, COST_PHASES, CompileWatcher,
                       CostGeometry, CostLedger, MetricsServer)

CFG = get_config("medverse-7b", smoke=True)

DIAMOND = ("<Plan> "
           "<Outline> Transient Step 1: q -> A ; Dependency: [] </Outline> "
           "<Outline> Transient Step 2: q -> B ; Dependency: [] </Outline> "
           "<Outline> Transient Step 3: A , B -> C ; Dependency: [1, 2] "
           "</Outline> </Plan>")


def make_tok():
    corpus = ["alpha beta gamma delta epsilon zeta eta theta iota kappa "
              "Transient Step 1: 2: 3: 4: 5: 6: 7: 8: "
              "Dependency: [] [1] [2] [1, 2] "
              "A -> B ; C D q x y z"]
    return Tokenizer.train(corpus)


@pytest.fixture(scope="module")
def setup():
    tok = make_tok()
    params = init_params(jax.random.PRNGKey(0), CFG)
    return tok, params


def make_engine(params, tok, **kw):
    base = dict(max_slots=4, page_size=4, n_pages=512, max_chain_len=256,
                max_step_tokens=6, max_conclusion_tokens=6)
    base.update(kw)
    return MedVerseEngine(params, CFG, tok, EngineConfig(**base))


# ------------------------------------------------------- geometry units ----
def test_geometry_from_model():
    g = CostGeometry.from_model(CFG, page_size=4, max_slots=4)
    assert g.n_layers == CFG.n_layers
    assert g.windows == (0,) * CFG.n_layers          # smoke: all global
    assert g.flops_per_pair == 4 * CFG.n_heads * CFG.resolved_head_dim
    assert g.kv_bytes_per_pair == (2 * CFG.n_kv_heads
                                   * CFG.resolved_head_dim * 4)  # float32
    assert g.kv_token_write_bytes == CFG.n_layers * g.kv_bytes_per_pair
    # global windows: every visible position is useful, causal pairs
    # are the lower triangle
    assert g.useful_pairs(10) == CFG.n_layers * 10
    assert g.causal_pairs(5) == CFG.n_layers * 15


def test_geometry_windowed_pairs():
    g = CostGeometry(n_heads=2, n_kv_heads=1, head_dim=4,
                     windows=(0, 3), dtype_bytes=2, page_size=4,
                     max_slots=2)
    assert g.useful_pairs(10) == 10 + 3              # global + clamped
    assert g.useful_pairs(2) == 2 + 2                # window not reached
    # windowed causal over n=5: rows see 1,2,3 then 3,3 positions
    assert g.causal_pairs(5) == 15 + (6 + 2 * 3)
    assert g.kv_bytes_per_pair == 2 * 1 * 4 * 2


def test_ledger_prefill_and_decode_arithmetic():
    g = CostGeometry(n_heads=1, n_kv_heads=1, head_dim=1,
                     windows=(0,), dtype_bytes=1, page_size=4,
                     max_slots=2)
    led = CostLedger(g)
    # prefill: bucket 8, 5 real tokens, 2 cached
    led.note_prefill(rid=0, n_prompt=5, n_cached=2, bucket=8)
    p = led.totals["prefill"]
    assert p["attn_flops"] == 4 * 64                 # 4*H*D * bucket^2
    assert p["useful_kv"] == 15 and p["padded_kv"] == 64 - 15
    assert p["kv_write_bytes"] == 3 * 2              # (5-2) * 2*K*D*B
    assert p["kv_read_bytes"] == 0
    # dense decode: one real row (visible 6) in a 2-slot batch, bucket 8
    led.note_decode([(0, 6, False)], s_bucket=8, pages=[2],
                    backend="dense")
    d = led.totals["decode"]
    assert d["attn_flops"] == 4 * (8 + 8)            # real row + pad row
    assert d["useful_kv"] == 6 and d["padded_kv"] == (8 - 6) + 8
    assert d["padded_rows"] == 1 and d["page_gathers"] == 2
    assert d["steps"] == 1 and d["rows"] == 1
    # pallas decode: pad rows skipped, compute follows the page run
    led2 = CostLedger(g)
    led2.note_decode([(1, 6, True)], s_bucket=8, pages=[2],
                     backend="pallas")
    assert led2.totals["spec_verify"]["attn_flops"] == 4 * 2 * 4
    assert led2.totals["spec_verify"]["padded_kv"] == 8 - 6
    assert led2.totals["decode"]["padded_rows"] == 1
    assert led2.totals["spec_verify"]["steps"] == 1
    # per-request attribution mirrors the totals it contributed
    assert led.requests[0]["prefill"]["useful_kv"] == 15
    summary = led.summary()
    assert summary["useful_kv"] == 15 + 6
    assert set(led.request_summary(99)) == set(COST_PHASES)  # zero-filled
    assert 0.0 < led.padding_waste_ratio() < 1.0


# -------------------------------------------------- engine integration -----
def test_cost_accounting_is_passive(setup):
    """Temp-0 output text and decode-iteration counts are bit-identical
    with cost accounting on or off, and the off engine exports no cost
    metrics."""
    tok, params = setup
    prompts = ["q alpha beta", "q beta gamma"]
    on = make_engine(params, tok, plan_override=DIAMOND)
    r_on = on.generate(prompts)
    off = make_engine(params, tok, plan_override=DIAMOND,
                      cost_accounting=False)
    r_off = off.generate(prompts)
    assert [r.text for r in r_on] == [r.text for r in r_off]
    assert on.total_iters == off.total_iters
    assert off.cost is None
    snap_off = off.metrics_registry().snapshot()
    assert not any(k.startswith("medverse_cost_") for k in snap_off)
    snap_on = on.metrics_registry().snapshot()
    assert snap_on["medverse_cost_decode_steps_total"] == on.total_iters


def test_dense_totals_close_against_bucket_hist(setup):
    """Independent reconstruction: under the dense backend every decode
    step computes max_slots * s_bucket pairs per layer, so the ledger's
    decode+spec FLOPs must equal flops_per_pair * n_layers * max_slots *
    sum(bucket * count) exactly — same for KV reads."""
    tok, params = setup
    eng = make_engine(params, tok, plan_override=DIAMOND,
                      attention_backend="dense")
    eng.generate(["q alpha beta", "q beta gamma"])
    g = eng.cost.geom
    pairs = g.n_layers * g.max_slots * sum(
        b * n for b, n in eng.bucket_hist.items())
    decode_flops = (eng.cost.totals["decode"]["attn_flops"]
                    + eng.cost.totals["spec_verify"]["attn_flops"])
    assert decode_flops == g.flops_per_pair * pairs
    decode_reads = (eng.cost.totals["decode"]["kv_read_bytes"]
                    + eng.cost.totals["spec_verify"]["kv_read_bytes"])
    assert decode_reads == g.kv_bytes_per_pair * pairs
    # useful + padded = computed, on every phase
    for ph in COST_PHASES:
        t = eng.cost.totals[ph]
        assert t["useful_kv"] >= 0 and t["padded_kv"] >= 0
    assert eng.cost.total("useful_kv") + eng.cost.total("padded_kv") \
        == pairs + eng.cost.totals["prefill"]["useful_kv"] \
        + eng.cost.totals["prefill"]["padded_kv"]
    # decode writes exactly one token per real row
    assert eng.cost.total("kv_write_bytes") % g.kv_token_write_bytes == 0


def test_cost_totals_deterministic_across_runs(setup):
    tok, params = setup
    summaries = []
    for _ in range(2):
        eng = make_engine(params, tok, plan_override=DIAMOND)
        eng.generate(["q alpha beta", "q beta gamma"])
        summaries.append(eng.cost.summary())
    assert summaries[0] == summaries[1]


@pytest.mark.parametrize("backend", ["dense", "pallas"])
def test_no_recompiles_after_full_warmup(setup, backend):
    tok, params = setup
    eng = make_engine(params, tok, plan_override=DIAMOND,
                      attention_backend=backend, kernel_interpret=True)
    eng.warmup()
    assert eng.compiles.warmup_step is not None
    eng.generate(["q alpha beta", "q beta gamma"])
    assert eng.compiles.recompiles_after_warmup == 0
    snap = eng.metrics_registry().snapshot()
    assert snap["medverse_recompiles_after_warmup_total"] == 0
    assert snap["medverse_compiles_total"] == eng.compiles.compiles_total


def test_partial_warmup_detects_recompile(setup):
    """Warming only the smallest bucket makes the first 128-wide dispatch
    a detected recompile — the counter CI gates to zero actually fires
    when the invariant is broken."""
    tok, params = setup
    eng = make_engine(params, tok, plan_override=DIAMOND,
                      attention_backend="dense")
    eng.warmup(buckets=[64])
    eng.generate(["q alpha beta", "q beta gamma"])
    assert 128 in eng.bucket_hist                    # wide bucket reached
    assert eng.compiles.recompiles_after_warmup >= 1
    assert ("decode", "dense", 128) in eng.compiles.seen


def test_compile_watcher_units():
    w = CompileWatcher()
    assert w.note(("decode", "dense", 64)) is True
    assert w.note(("decode", "dense", 64)) is False   # cached
    w.finish_warmup(step=5)
    w.finish_warmup(step=9)                           # idempotent
    assert w.warmup_step == 5
    assert w.note(("decode", "dense", 128)) is True
    assert w.compiles_total == 2
    assert w.recompiles_after_warmup == 1


def test_request_end_event_carries_cost(setup):
    tok, params = setup
    eng = make_engine(params, tok, plan_override=DIAMOND, trace=True)
    eng.generate(["q alpha beta"])
    ends = [ev for ev in eng.obs.events
            if ev["ph"] == "E" and ev["name"] == "request"]
    assert len(ends) == 1
    cost = ends[0]["args"]["cost"]
    assert set(cost) == set(COST_PHASES)
    assert set(cost["decode"]) == set(COST_FIELDS)
    assert cost["decode"]["useful_kv"] > 0
    assert cost["prefill"]["steps"] == 1
    # counter tracks sampled: cumulative cost series present in trace
    names = {ev["name"] for ev in eng.obs.events if ev["ph"] == "C"}
    assert {"cost_attn_flops", "cost_kv_bytes", "cost_padding",
            "cost_pages"} <= names


# ------------------------------------------------------ /metrics server ----
def _scrape(url: str) -> str:
    with urllib.request.urlopen(url, timeout=10) as resp:
        assert resp.status == 200
        return resp.read().decode()


def test_metrics_server_scrape_and_parse(setup):
    """Start the live endpoint against a served engine, scrape /metrics,
    assert the cost counters are present and every sample line parses as
    Prometheus text exposition."""
    tok, params = setup
    eng = make_engine(params, tok, plan_override=DIAMOND)
    eng.generate(["q alpha beta"])
    srv = MetricsServer(
        lambda: eng.metrics_registry().to_prom_text(), port=0).start()
    try:
        assert _scrape(f"{srv.address}/healthz").strip() == "ok"
        text = _scrape(f"{srv.address}/metrics")
        assert "medverse_cost_decode_attn_flops_total" in text
        assert "medverse_cost_prefill_kv_write_bytes_total" in text
        assert "medverse_recompiles_after_warmup_total" in text
        assert "medverse_padding_waste_ratio" in text
        assert "medverse_decode_chain_bucket_bucket" in text  # histogram
        samples = 0
        for ln in text.splitlines():
            if not ln or ln.startswith("#"):
                continue
            name_part, _, value = ln.rpartition(" ")
            assert name_part and name_part[0].isalpha(), ln
            float(value)                          # parseable sample
            samples += 1
        assert samples > 20
        # unknown path -> 404
        with pytest.raises(urllib.error.HTTPError) as exc:
            _scrape(f"{srv.address}/nope")
        assert exc.value.code == 404
        # cost counters in the scrape match the ledger exactly
        flops = eng.cost.totals["decode"]["attn_flops"]
        assert f"medverse_cost_decode_attn_flops_total {flops}" in text
    finally:
        srv.close()


# ------------------------------------------------------- trace_view CLI ----
def test_trace_view_render_and_self_diff(setup, tmp_path):
    tok, params = setup
    path = str(tmp_path / "t.jsonl")
    eng = make_engine(params, tok, plan_override=DIAMOND, trace=path)
    eng.warmup()
    eng.generate(["q alpha beta", "q beta gamma"])
    eng.dump_trace()
    proc = subprocess.run(
        [sys.executable, "tools/trace_view.py", path],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    out = proc.stdout
    assert "prefill" in out and "spec_verify" in out
    assert "after warmup 0" in out
    # flops in the table match the ledger
    assert f"{eng.cost.totals['decode']['attn_flops']:,}" in out
    # a trace diffed against itself reports no changes
    proc = subprocess.run(
        [sys.executable, "tools/trace_view.py", "--diff", path, path],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "<-- changed" not in proc.stdout
    assert "recompiles after warmup" in proc.stdout
