"""Per-kernel validation: shape/dtype sweeps + assert_allclose against
the pure-jnp oracles (interpret=True executes the kernel body on CPU).
Also cross-checks the kernels against the *model* implementations they
accelerate."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import ReasoningDAG, topology_from_dag
from repro.kernels.dag_attention.ops import dag_attention
from repro.kernels.dag_attention.ref import dag_attention_ref
from repro.kernels.decode_attention.ops import paged_decode_attention
from repro.kernels.decode_attention.ref import paged_decode_attention_ref
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.rwkv6_scan.ops import rwkv6_scan
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5)


def make_topo(batch, s, seed=0):
    rng = np.random.default_rng(seed)
    dag = ReasoningDAG.from_deps({0: [], 1: [], 2: [0, 1], 3: [0]})
    lens = {t: int(rng.integers(3, 8)) for t in dag.nodes}
    prefix = int(rng.integers(4, 10))
    topo, _ = topology_from_dag(dag, prefix, lens, 4)
    topo = topo.pad_to(s)
    tile = lambda a: jnp.asarray(np.stack([a] * batch))
    return tile(topo.seg_id), tile(topo.layer_id), tile(topo.pos_id)


# --------------------------------------------------------- dag_attention ---
@pytest.mark.parametrize("b,s,nh,nkv,hd,bq,bk", [
    (1, 32, 4, 4, 8, 8, 8),       # MHA
    (2, 64, 4, 2, 16, 16, 16),    # GQA
    (1, 64, 8, 1, 32, 32, 16),    # MQA, uneven blocks
    (2, 48, 4, 2, 16, 16, 16),    # padding path (48 -> 64)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dag_attention_sweep(b, s, nh, nkv, hd, bq, bk, dtype):
    key = jax.random.PRNGKey(s + nh)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, nh, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, nkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, nkv, hd), dtype)
    seg, lay, pos = make_topo(b, s)
    out = dag_attention(q, k, v, seg, lay, pos, block_q=bq, block_k=bk,
                        interpret=True)
    ref = dag_attention_ref(
        q.transpose(0, 2, 1, 3).astype(jnp.float32),
        k.transpose(0, 2, 1, 3).astype(jnp.float32),
        v.transpose(0, 2, 1, 3).astype(jnp.float32),
        seg, lay, pos).transpose(0, 2, 1, 3)
    valid = np.asarray(seg[0] != -1)
    np.testing.assert_allclose(
        np.asarray(out, np.float32)[:, valid],
        np.asarray(ref, np.float32)[:, valid], **_tol(dtype))


def test_dag_attention_window():
    b, s, nh, nkv, hd = 1, 64, 4, 2, 16
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, nh, hd))
    k = jax.random.normal(ks[1], (b, s, nkv, hd))
    v = jax.random.normal(ks[2], (b, s, nkv, hd))
    seg, lay, pos = make_topo(b, s)
    out = dag_attention(q, k, v, seg, lay, pos, window=6,
                        block_q=16, block_k=16, interpret=True)
    ref = dag_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), seg, lay, pos,
        window=6).transpose(0, 2, 1, 3)
    valid = np.asarray(seg[0] != -1)
    np.testing.assert_allclose(np.asarray(out)[:, valid],
                               np.asarray(ref)[:, valid],
                               rtol=2e-5, atol=2e-5)


def test_dag_attention_matches_model_attention():
    """Kernel == the model's naive masked attention on real topology."""
    from repro.core.masks import dag_attention_allowed, mask_bias
    b, s, nh, nkv, hd = 2, 64, 4, 2, 8
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, nh, hd))
    k = jax.random.normal(ks[1], (b, s, nkv, hd))
    v = jax.random.normal(ks[2], (b, s, nkv, hd))
    seg, lay, pos = make_topo(b, s, seed=5)
    out = dag_attention(q, k, v, seg, lay, pos, block_q=8, block_k=8,
                        interpret=True)
    allowed = dag_attention_allowed(seg, lay)
    g = nh // nkv
    qg = q.reshape(b, s, nkv, g, hd).astype(jnp.float32)
    sc = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    sc = sc / np.sqrt(hd) + mask_bias(allowed)[:, None, None]
    w = jax.nn.softmax(sc, axis=-1)
    ref = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    ref = ref.reshape(b, s, nh, hd)
    valid = np.asarray(seg[0] != -1)
    np.testing.assert_allclose(np.asarray(out)[:, valid],
                               np.asarray(ref)[:, valid],
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------ decode_attention ---
@pytest.mark.parametrize("b,nh,nkv,hd,npages,pg,pmax", [
    (2, 4, 2, 16, 16, 8, 4),
    (4, 8, 8, 8, 32, 4, 8),       # MHA
    (1, 4, 1, 32, 8, 16, 3),      # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, nh, nkv, hd, npages, pg, pmax, dtype):
    rng = np.random.default_rng(b + nh)
    key = jax.random.PRNGKey(b)
    q = jax.random.normal(key, (b, nh, hd), dtype)
    kp = jax.random.normal(jax.random.PRNGKey(1), (npages, pg, nkv, hd), dtype)
    vp = jax.random.normal(jax.random.PRNGKey(2), (npages, pg, nkv, hd), dtype)
    pos = jnp.asarray(rng.integers(0, 50, (npages, pg)), jnp.int32)
    pt = jnp.asarray(rng.integers(0, npages, (b, pmax)), jnp.int32)
    pv = jnp.asarray(rng.integers(0, pg + 1, (b, pmax)), jnp.int32)
    qpos = jnp.asarray(rng.integers(10, 60, (b,)), jnp.int32)
    out = paged_decode_attention(q, kp, vp, pos, pt, pv, qpos,
                                 interpret=True)
    ref = paged_decode_attention_ref(
        q.reshape(b, nkv, nh // nkv, hd).astype(jnp.float32),
        kp.astype(jnp.float32), vp.astype(jnp.float32),
        pos, pt, pv, qpos).reshape(b, nh, hd)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_decode_attention_fork_join_semantics():
    """Two forked streams sharing prefix pages then a joined stream over
    both branches — kernel visibility equals chain content."""
    rng = np.random.default_rng(0)
    npages, pg, nkv, hd, nh = 8, 4, 2, 8, 4
    kp = jax.random.normal(jax.random.PRNGKey(1), (npages, pg, nkv, hd))
    vp = jax.random.normal(jax.random.PRNGKey(2), (npages, pg, nkv, hd))
    # prefix = pages 0,1 (pos 0..7); branch A page 2 (pos 8..11);
    # branch B page 3 (pos 8..11, fork-aligned); join reads all four.
    pos = jnp.asarray(
        np.stack([np.arange(4), np.arange(4, 8), np.arange(8, 12),
                  np.arange(8, 12)] + [np.zeros(4)] * 4), jnp.int32)
    pt = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
    pv = jnp.asarray([[4, 4, 4, 4]], jnp.int32)
    q = jax.random.normal(jax.random.PRNGKey(3), (1, nh, hd))
    qpos = jnp.asarray([12], jnp.int32)
    out = paged_decode_attention(q, kp, vp, pos, pt, pv, qpos,
                                 interpret=True)
    ref = paged_decode_attention_ref(
        q.reshape(1, nkv, nh // nkv, hd), kp, vp, pos, pt, pv,
        qpos).reshape(1, nh, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------ rglru_scan ---
@pytest.mark.parametrize("b,s,w", [(1, 16, 8), (2, 64, 32), (3, 128, 128),
                                   (2, 96, 24)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan_sweep(b, s, w, dtype):
    key = jax.random.PRNGKey(s)
    a = jax.nn.sigmoid(jax.random.normal(key, (b, s, w))).astype(dtype)
    bb = jax.random.normal(jax.random.PRNGKey(1), (b, s, w), dtype)
    h0 = jax.random.normal(jax.random.PRNGKey(2), (b, w), jnp.float32)
    out = rglru_scan(a, bb, h0, interpret=True)
    ref = rglru_scan_ref(a.astype(jnp.float32), bb.astype(jnp.float32), h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               **_tol(dtype))


def test_rglru_scan_matches_model_block():
    """Kernel equals the model's associative-scan path (zero init)."""
    from repro.models.rglru import rglru_scan_ref as model_scan
    b, s, w = 2, 32, 16
    a = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(0), (b, s, w))) * 0.98
    bb = jax.random.normal(jax.random.PRNGKey(1), (b, s, w))
    out = rglru_scan(a, bb, interpret=True)
    ref = model_scan(a, bb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------ rwkv6_scan ---
@pytest.mark.parametrize("b,s,h,n", [(1, 16, 2, 8), (2, 64, 4, 16),
                                     (1, 32, 1, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan_sweep(b, s, h, n, dtype):
    ks = jax.random.split(jax.random.PRNGKey(s + n), 6)
    r = jax.random.normal(ks[0], (b, s, h, n), dtype)
    k = jax.random.normal(ks[1], (b, s, h, n), dtype) * 0.3
    v = jax.random.normal(ks[2], (b, s, h, n), dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, n))).astype(dtype)
    u = jax.random.normal(ks[4], (h, n), jnp.float32) * 0.1
    s0 = jax.random.normal(ks[5], (b, h, n, n), jnp.float32) * 0.1
    out = rwkv6_scan(r, k, v, w, u, s0, interpret=True)
    ref = rwkv6_scan_ref(r.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), w.astype(jnp.float32), u, s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               **_tol(dtype))


def test_rwkv6_scan_matches_model_wkv():
    """Kernel equals models.rwkv.wkv_scan_ref on flat (B,S,D) layout."""
    from repro.models.rwkv import wkv_scan_ref
    b, s, h, n = 2, 24, 2, 8
    d = h * n
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    r = jax.random.normal(ks[0], (b, s, d))
    k = jax.random.normal(ks[1], (b, s, d)) * 0.3
    v = jax.random.normal(ks[2], (b, s, d))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, d)))
    u = jax.random.normal(ks[4], (d,)) * 0.1
    y_model, _ = wkv_scan_ref(r, k, v, w, u, n)
    y_kernel = rwkv6_scan(
        r.reshape(b, s, h, n), k.reshape(b, s, h, n),
        v.reshape(b, s, h, n), w.reshape(b, s, h, n),
        u.reshape(h, n), interpret=True)
    np.testing.assert_allclose(np.asarray(y_kernel).reshape(b, s, d),
                               np.asarray(y_model), rtol=2e-5, atol=2e-5)
