"""repro — MedVerse (ACL 2026) reproduced as a production-grade JAX
framework: DAG-structured parallel medical reasoning with a Petri-net
scheduler, topology-aware attention, and a fork/join serving engine.
"""

__version__ = "0.1.0"
