"""DAG representation for medical reasoning topologies.

The paper (Sec. 3.1) models reasoning as a DAG ``G = (V, E)`` with three
node roles: *source* (in-degree 0, clinical entities grounded in the
question), *hypothesis* (internal), and *conclusion* (out-degree 0).
Edges are forward-only reasoning steps.

This module is pure Python (host-side): validity checking, topological
layering (the "frontier layers" that drive the attention mask), and
conversion helpers used by both the Curator and the Engine.
"""

from __future__ import annotations

import dataclasses
from typing import (Dict, FrozenSet, Iterable, List, Mapping, Optional,
                    Sequence, Tuple)


class CycleError(ValueError):
    """Raised when a supposed DAG contains a cycle (Curator validity check)."""


@dataclasses.dataclass(frozen=True)
class ReasoningDAG:
    """An immutable reasoning DAG over integer node ids.

    ``deps[v]`` lists the predecessors of node ``v`` (its in-edges). Node
    ids are arbitrary hashable ints; the Curator uses step indices.
    """

    nodes: Tuple[int, ...]
    deps: Mapping[int, Tuple[int, ...]]
    # Sparse stage typing: only non-default entries are stored, so a DAG
    # built from a pre-stage plan compares equal to one built with
    # all-"reason" stages. Query via :meth:`stage_of`.
    stages: Mapping[int, str] = dataclasses.field(default_factory=dict)

    @staticmethod
    def from_deps(deps: Mapping[int, Sequence[int]],
                  stages: Optional[Mapping[int, str]] = None,
                  ) -> "ReasoningDAG":
        nodes = tuple(sorted(deps.keys()))
        norm = {v: tuple(sorted(set(deps[v]))) for v in nodes}
        for v, ps in norm.items():
            for p in ps:
                if p not in norm:
                    raise ValueError(f"node {v} depends on unknown node {p}")
                if p == v:
                    raise CycleError(f"self-loop at node {v}")
        st = {v: s for v, s in (stages or {}).items()
              if v in norm and s != "reason"}
        dag = ReasoningDAG(nodes=nodes, deps=norm, stages=st)
        dag.topological_layers()  # raises CycleError if cyclic
        return dag

    def stage_of(self, v: int) -> str:
        """Stage tag of node ``v`` ("reason" unless tagged otherwise)."""
        return self.stages.get(v, "reason")

    # -- structure queries -------------------------------------------------
    def predecessors(self, v: int) -> Tuple[int, ...]:
        return self.deps[v]

    def successors(self, v: int) -> Tuple[int, ...]:
        return tuple(u for u in self.nodes if v in self.deps[u])

    def sources(self) -> Tuple[int, ...]:
        return tuple(v for v in self.nodes if not self.deps[v])

    def sinks(self) -> Tuple[int, ...]:
        succ_any = {p for v in self.nodes for p in self.deps[v]}
        return tuple(v for v in self.nodes if v not in succ_any)

    def edges(self) -> Tuple[Tuple[int, int], ...]:
        return tuple((p, v) for v in self.nodes for p in self.deps[v])

    # -- topology ----------------------------------------------------------
    def topological_layers(self) -> List[List[int]]:
        """Kahn layering: layer k = nodes whose longest path from a source
        has length k. This is exactly the paper's "frontier layer"
        assignment used for the mutual-exclusion mask (Eq. 3) under
        maximally-parallel scheduling.
        """
        depth: Dict[int, int] = {}
        remaining = set(self.nodes)
        indeg = {v: len(self.deps[v]) for v in self.nodes}
        frontier = [v for v in self.nodes if indeg[v] == 0]
        for v in frontier:
            depth[v] = 0
        processed = 0
        queue = list(frontier)
        while queue:
            v = queue.pop()
            processed += 1
            remaining.discard(v)
            for u in self.successors(v):
                depth[u] = max(depth.get(u, 0), depth[v] + 1)
                indeg[u] -= 1
                if indeg[u] == 0:
                    queue.append(u)
        if processed != len(self.nodes):
            raise CycleError(f"cycle among nodes {sorted(remaining)}")
        n_layers = max(depth.values(), default=-1) + 1
        layers: List[List[int]] = [[] for _ in range(n_layers)]
        for v, d in depth.items():
            layers[d].append(v)
        return [sorted(layer) for layer in layers]

    def depth(self) -> int:
        """Topological depth D — the paper's O(D) latency bound."""
        return len(self.topological_layers())

    def ancestors(self, v: int) -> FrozenSet[int]:
        seen: set = set()
        stack = list(self.deps[v])
        while stack:
            p = stack.pop()
            if p in seen:
                continue
            seen.add(p)
            stack.extend(self.deps[p])
        return frozenset(seen)

    def is_linear_chain(self) -> bool:
        return all(len(layer) == 1 for layer in self.topological_layers())

    def classify_topology(self) -> str:
        """Paper Table 3 taxonomy: linear / independent-chains / intersecting."""
        if self.is_linear_chain():
            return "single_linear_chain"
        # Intersecting: any transition that merges evidence (in-degree > 1)
        # or feeds multiple downstream steps (out-degree > 1). Chains that
        # only converge at the *conclusion stage* (outside the DAG) remain
        # "independent" — paper Table 3 taxonomy.
        has_join = any(len(self.deps[v]) > 1 for v in self.nodes)
        has_fork = any(len(self.successors(v)) > 1 for v in self.nodes)
        if has_join or has_fork:
            return "complex_intersecting"
        return "multiple_independent_chains"


def merge_paths_to_dag(paths: Iterable[Sequence[str]]) -> Tuple[ReasoningDAG, Dict[int, Tuple[str, Tuple[str, ...]]]]:
    """Consolidate linear entity paths into a transition-level DAG.

    This is the Curator's *Think-then-Map* consolidation (Sec. 3.4 / B
    Phase 3): each edge ``A -> B`` of each path becomes a candidate
    transition; edges converging on the same target entity are aggregated
    into one transition (the paper's many-to-one mapping); a transition
    depends on every transition that *produces* one of its input entities.

    Returns (dag, meta) where ``meta[node] = (target_entity, source_entities)``.
    """
    producers: Dict[str, int] = {}  # entity -> transition id producing it
    inputs: Dict[int, set] = {}
    order: List[str] = []  # target entities in first-seen order
    for path in paths:
        for a, b in zip(path[:-1], path[1:]):
            if b not in producers:
                tid = len(order)
                producers[b] = tid
                order.append(b)
                inputs[tid] = set()
            inputs[producers[b]].add(a)
    deps: Dict[int, List[int]] = {}
    meta: Dict[int, Tuple[str, Tuple[str, ...]]] = {}
    for tgt, tid in producers.items():
        srcs = sorted(inputs[tid])
        deps[tid] = sorted(
            {producers[s] for s in srcs if s in producers and producers[s] != tid}
        )
        meta[tid] = (tgt, tuple(srcs))
    # Drop dependencies that would create cycles (entity revisits): the
    # Curator's validity check rejects these paths upstream; here we guard.
    dag = ReasoningDAG.from_deps(deps)
    return dag, meta
