"""MedVerse core: DAG + Petri-net execution model, plan format, topology
metadata, and DAG attention mask construction (the paper's primary
contribution, Secs. 3-4.2)."""

from .dag import CycleError, ReasoningDAG, merge_paths_to_dag
from .masks import (
    NEG_INF,
    ancestor_attention_allowed,
    dag_attention_allowed,
    decode_visibility,
    mask_bias,
    sliding_window_allowed,
)
from .petri import (
    ColoredToken,
    FiredTransition,
    Marking,
    PetriNet,
    PetriScheduler,
    Transition,
)
from .plan import (
    OutlineStep,
    PlanParseError,
    ReasoningPlan,
    parse_answer,
    parse_conclusion,
    parse_plan,
    parse_steps,
    plan_is_complete,
    render_conclusion,
    render_step,
    render_think,
)
from .topology import (
    PAD_SEG,
    SegmentSpec,
    SequenceTopology,
    build_topology,
    dag_depth_tokens,
    linear_topology,
    topology_from_dag,
)

__all__ = [
    "CycleError",
    "ReasoningDAG",
    "merge_paths_to_dag",
    "NEG_INF",
    "ancestor_attention_allowed",
    "dag_attention_allowed",
    "decode_visibility",
    "mask_bias",
    "sliding_window_allowed",
    "ColoredToken",
    "FiredTransition",
    "Marking",
    "PetriNet",
    "PetriScheduler",
    "Transition",
    "OutlineStep",
    "PlanParseError",
    "ReasoningPlan",
    "parse_answer",
    "parse_conclusion",
    "parse_plan",
    "parse_steps",
    "plan_is_complete",
    "render_conclusion",
    "render_step",
    "render_think",
    "PAD_SEG",
    "SegmentSpec",
    "SequenceTopology",
    "build_topology",
    "dag_depth_tokens",
    "linear_topology",
    "topology_from_dag",
]
