"""Parser/serializer for the MedVerse structured generation format.

The paper's three-stage flow (Sec. 3.4, Fig. 3):

    <Think> ...linear reasoning paths... </Think>
    <Plan>
      <Outline> Transient Step 1: A -> B; Dependency: [] </Outline>
      <Outline> Transient Step 4: B, C -> D; Dependency: [1, 2] </Outline>
    </Plan>
    <Execution>
      <Step> Transient Step 1: A -> B ...reasoning text... </Step>
      ...
    </Execution>
    <Conclusion> Explanation: ... Answer: x) ... </Conclusion>

The engine pauses at ``</Plan>`` (Phase I -> Phase II trigger), parses the
outlines into a ReasoningDAG, and instantiates the Petri net. The Curator
uses the serializer to render training data in exactly this format.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Tuple

from .dag import ReasoningDAG

PLAN_OPEN = "<Plan>"
PLAN_CLOSE = "</Plan>"
# Closed stage vocabulary. "reason" is the default; a step only carries
# an explicit ``; Stage: critic`` clause when it deviates, so every
# pre-stage plan/corpus serializes and parses byte-identically.
STAGES = ("reason", "critic", "guardrail")
DEFAULT_STAGE = "reason"
OUTLINE_RE = re.compile(
    r"<Outline>\s*Transient Step\s+(\d+)\s*:\s*(.*?)\s*;?\s*"
    r"Dependency\s*:\s*\[([^\]]*)\]\s*"
    # optional stage clause; trailing <unk>s absorb a stage clause whose
    # words fell out of a stale tokenizer's vocabulary (the outline then
    # degrades to the default "reason" stage instead of being dropped)
    r"(?:;?\s*Stage\s*:\s*(\w+|<unk>)\s*)?(?:;?\s*(?:<unk>\s*)*)?</Outline>",
    re.DOTALL,
)
STEP_OPEN_RE = re.compile(r"<Step>\s*Transient Step\s+(\d+)\s*:", re.DOTALL)
STEP_RE = re.compile(
    r"<Step>\s*Transient Step\s+(\d+)\s*:\s*(.*?)</Step>", re.DOTALL
)
CONCLUSION_RE = re.compile(r"<Conclusion>(.*?)(?:</Conclusion>|$)", re.DOTALL)


class PlanParseError(ValueError):
    pass


@dataclasses.dataclass(frozen=True)
class OutlineStep:
    index: int                 # 1-based step index as written
    label: str                 # "A, B -> C" step description
    dependencies: Tuple[int, ...]  # 1-based indices of prerequisite steps
    stage: str = DEFAULT_STAGE     # "reason" | "critic" | "guardrail"


@dataclasses.dataclass(frozen=True)
class ReasoningPlan:
    steps: Tuple[OutlineStep, ...]

    def to_dag(self) -> ReasoningDAG:
        """0-based transition DAG; raises on unknown deps or cycles —
        this is the engine's (and Curator's) DAG validity check."""
        ids = {s.index for s in self.steps}
        deps = {}
        for s in self.steps:
            for d in s.dependencies:
                if d not in ids:
                    raise PlanParseError(
                        f"step {s.index} depends on missing step {d}"
                    )
            deps[s.index - 1] = tuple(d - 1 for d in s.dependencies)
        return ReasoningDAG.from_deps(deps, stages=self.stages())

    def labels(self) -> Dict[int, str]:
        return {s.index - 1: s.label for s in self.steps}

    def stages(self) -> Dict[int, str]:
        return {s.index - 1: s.stage for s in self.steps}

    def serialize(self) -> str:
        # Spaced punctuation keeps the word-level tokenizer's entity
        # vocabulary clean ("A" vs "A;" would be distinct tokens). The
        # stage clause is emitted only for non-default stages, so plans
        # written before stage typing round-trip byte-identically.
        parts = [PLAN_OPEN]
        for s in self.steps:
            dep = " , ".join(str(d) for d in s.dependencies)
            dep = f"[ {dep} ]" if dep else "[ ]"
            stage = (f" ; Stage: {s.stage}"
                     if s.stage != DEFAULT_STAGE else "")
            parts.append(
                f"<Outline> Transient Step {s.index}: {s.label} ;"
                f" Dependency: {dep}{stage} </Outline>"
            )
        parts.append(PLAN_CLOSE)
        return " ".join(parts)


def parse_plan(text: str, lenient: bool = False) -> ReasoningPlan:
    """Parse the first <Plan>...</Plan> block out of generated text.

    ``lenient=True`` (engine-side): outlines whose dependency lists
    reference non-existent steps get those references dropped instead of
    failing the whole plan — graceful degradation for model-generated
    plans (cycles are still rejected downstream by ``to_dag``)."""
    start = text.find(PLAN_OPEN)
    end = text.find(PLAN_CLOSE)
    if start < 0 or end < 0 or end < start:
        raise PlanParseError("no complete <Plan> block found")
    block = text[start : end + len(PLAN_CLOSE)]
    steps: List[OutlineStep] = []
    for m in OUTLINE_RE.finditer(block):
        idx = int(m.group(1))
        label = " ".join(m.group(2).split())
        deps_raw = m.group(3).strip()
        deps: Tuple[int, ...] = ()
        if deps_raw:
            parsed = []
            for x in deps_raw.split(","):
                x = x.strip()
                if not x:
                    continue
                try:
                    parsed.append(int(x))
                except ValueError:
                    # model emitted garbage inside the bracket
                    if lenient:
                        continue
                    raise PlanParseError(
                        f"non-integer dependency {x!r} in step {idx}")
            deps = tuple(parsed)
        stage = (m.group(4) or DEFAULT_STAGE).lower()
        if stage not in STAGES:
            # model emitted a stage word outside the closed vocabulary
            # (or the word decoded as <unk> under a stale tokenizer)
            if lenient:
                stage = DEFAULT_STAGE
            else:
                raise PlanParseError(
                    f"unknown stage {stage!r} in step {idx}")
        steps.append(OutlineStep(index=idx, label=label, dependencies=deps,
                                 stage=stage))
    if not steps:
        raise PlanParseError("plan block contains no <Outline> entries")
    seen = set()
    uniq = []
    for s in steps:
        if s.index in seen:
            if lenient:
                continue
            raise PlanParseError(f"duplicate step index {s.index}")
        seen.add(s.index)
        uniq.append(s)
    steps = uniq
    if lenient:
        ids = {s.index for s in steps}
        steps = [
            OutlineStep(
                index=s.index, label=s.label,
                dependencies=tuple(d for d in s.dependencies
                                   if d in ids and d != s.index),
                stage=s.stage,
            )
            for s in steps
        ]
    return ReasoningPlan(steps=tuple(sorted(steps, key=lambda s: s.index)))


def plan_is_complete(text: str) -> bool:
    return PLAN_CLOSE in text


def parse_steps(text: str) -> Dict[int, str]:
    """Extract executed <Step> bodies keyed by 1-based step index."""
    return {
        int(m.group(1)): " ".join(m.group(2).split())
        for m in STEP_RE.finditer(text)
    }


def parse_conclusion(text: str) -> Optional[str]:
    m = CONCLUSION_RE.search(text)
    return " ".join(m.group(1).split()) if m else None


def parse_answer(text: str) -> Optional[str]:
    """Pull 'Answer: <option>' from a conclusion block."""
    conc = parse_conclusion(text)
    if conc is None:
        conc = text
    m = re.search(r"Answer\s*:\s*([^<\n]+)", conc)
    return m.group(1).strip() if m else None


def render_step(index: int, label: str, body: str) -> str:
    return f"<Step> Transient Step {index}: {label} {body} </Step>"


def render_conclusion(explanation: str, answer: str) -> str:
    return f"<Conclusion> Explanation: {explanation} Answer: {answer} </Conclusion>"


def render_think(paths: Sequence[str]) -> str:
    lines = " ".join(f"{i+1}. {p}" for i, p in enumerate(paths))
    return f"<Think> Finding Reasoning Path: {lines} </Think>"
