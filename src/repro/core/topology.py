"""Per-token topology metadata for MedVerse attention.

A structured training example is *packed* linearly as

    [prefix (prompt+think+plan)] [steps, frontier layer by layer] [conclusion]

and annotated with three O(S) int arrays that fully determine the DAG
attention mask (Eq. 3) and the adaptive position indices (Sec. 4.2):

    seg_id[i]   : which segment token i belongs to
                  (0 = linear prefix, 1..T = transient steps,
                   T+1 = conclusion; -1 = padding)
    layer_id[i] : frontier layer of that segment
                  (0 = prefix, 1.. = DAG layers, depth+1 = conclusion)
    pos_id[i]   : adaptive position index. Segments in the same frontier
                  layer share a start index (*fork alignment*); each layer
                  starts at the max end-position of all earlier layers
                  (*join = max over predecessor branches*, synchronized at
                  the frontier as in Sec. 3.3's execution loop).

Keeping the metadata O(S) instead of materializing the (S,S) mask is what
lets the Pallas kernel stream it through VMEM (see DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .dag import ReasoningDAG

PAD_SEG = -1


@dataclasses.dataclass(frozen=True)
class SegmentSpec:
    """One contiguous packed segment."""

    seg_id: int
    layer_id: int
    length: int


@dataclasses.dataclass
class SequenceTopology:
    """Packed per-token metadata for one example."""

    seg_id: np.ndarray    # (S,) int32
    layer_id: np.ndarray  # (S,) int32
    pos_id: np.ndarray    # (S,) int32
    # ancestor matrix over segment ids (incl. prefix=0 and conclusion),
    # anc[s, t] == True iff tokens of segment s may attend to segment t
    # under the *strict* ancestor mask (beyond-paper consistency variant).
    seg_visible: np.ndarray  # (n_seg, n_seg) bool

    @property
    def length(self) -> int:
        return int(self.seg_id.shape[0])

    def pad_to(self, seq_len: int) -> "SequenceTopology":
        s = self.length
        if s > seq_len:
            raise ValueError(f"sequence {s} longer than pad target {seq_len}")
        pad = seq_len - s

        def _pad(a: np.ndarray, fill: int) -> np.ndarray:
            return np.concatenate([a, np.full((pad,), fill, a.dtype)])

        return SequenceTopology(
            seg_id=_pad(self.seg_id, PAD_SEG),
            layer_id=_pad(self.layer_id, -1),
            pos_id=_pad(self.pos_id, 0),
            seg_visible=self.seg_visible,
        )


def build_topology(segments: Sequence[SegmentSpec],
                   visible: Optional[np.ndarray] = None) -> SequenceTopology:
    """Pack segments (already in linear order) into per-token arrays.

    Adaptive positions: all segments within a frontier layer start at the
    same index = max end-position over all preceding layers.
    """
    seg_ids: List[int] = []
    layer_ids: List[int] = []
    pos_ids: List[int] = []
    layer_start: Dict[int, int] = {}
    layer_max_end: Dict[int, int] = {}
    ordered_layers = []
    for seg in segments:
        if seg.layer_id not in layer_start:
            prev_end = 0
            for l in ordered_layers:
                prev_end = max(prev_end, layer_max_end[l])
            layer_start[seg.layer_id] = prev_end
            layer_max_end[seg.layer_id] = prev_end
            ordered_layers.append(seg.layer_id)
        start = layer_start[seg.layer_id]
        end = start + seg.length
        layer_max_end[seg.layer_id] = max(layer_max_end[seg.layer_id], end)
        seg_ids.extend([seg.seg_id] * seg.length)
        layer_ids.extend([seg.layer_id] * seg.length)
        pos_ids.extend(range(start, end))
    n_seg = max((s.seg_id for s in segments), default=0) + 1
    if visible is None:
        visible = np.ones((n_seg, n_seg), dtype=bool)
    return SequenceTopology(
        seg_id=np.asarray(seg_ids, np.int32),
        layer_id=np.asarray(layer_ids, np.int32),
        pos_id=np.asarray(pos_ids, np.int32),
        seg_visible=visible,
    )


def topology_from_dag(
    dag: ReasoningDAG,
    prefix_len: int,
    step_lens: Dict[int, int],
    conclusion_len: int,
) -> Tuple[SequenceTopology, List[int]]:
    """Build packed topology for a full structured example.

    Packed order: prefix, then steps grouped by frontier layer (tid order
    inside a layer), then conclusion. Returns the topology plus the packed
    step order (list of dag node ids) so callers can lay out token spans.

    seg id mapping: prefix=0, dag node t -> seg t+1, conclusion = T+1.
    """
    layers = dag.topological_layers()
    segments: List[SegmentSpec] = [SegmentSpec(0, 0, prefix_len)]
    packed_order: List[int] = []
    for li, layer in enumerate(layers):
        for tid in layer:
            segments.append(SegmentSpec(tid + 1, li + 1, step_lens[tid]))
            packed_order.append(tid)
    n_steps = len(dag.nodes)
    conc_seg = n_steps + 1
    segments.append(SegmentSpec(conc_seg, len(layers) + 1, conclusion_len))

    # strict ancestor visibility (prefix visible to all; conclusion sees all)
    n_seg = n_steps + 2
    vis = np.zeros((n_seg, n_seg), dtype=bool)
    vis[:, 0] = True  # everyone sees the prefix
    for t in dag.nodes:
        s = t + 1
        vis[s, s] = True
        for a in dag.ancestors(t):
            vis[s, a + 1] = True
    vis[conc_seg, :] = True
    vis[0, 0] = True
    return build_topology(segments, visible=vis), packed_order


def linear_topology(length: int) -> SequenceTopology:
    """Plain causal sequence (baseline AR models / planning phase)."""
    return build_topology([SegmentSpec(0, 0, length)])


def dag_depth_tokens(topo: SequenceTopology) -> int:
    """Critical-path token count = max adaptive position + 1 (the O(D)
    latency bound the paper claims; used by benchmarks)."""
    valid = topo.seg_id != PAD_SEG
    if not valid.any():
        return 0
    return int(topo.pos_id[valid].max()) + 1
