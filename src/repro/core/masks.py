"""DAG attention mask construction (paper Eq. 3) — pure jnp.

Two variants:

* ``dag_attention_allowed`` — the paper-faithful mask: causal in packed
  order, plus mutual exclusion between different steps in the same
  frontier layer.
* ``ancestor_attention_allowed`` — strict variant (beyond-paper
  "consistency mode"): a token may only attend to segments that are DAG
  ancestors of its own segment. This exactly matches what the engine's
  fork/join KV chains expose at inference time; see EXPERIMENTS.md §Perf
  for the train/inference-consistency ablation.

These are the oracles for the Pallas ``dag_attention`` kernel and the
mask path used by the pure-JAX model on CPU.
"""

from __future__ import annotations

import jax.numpy as jnp

from .topology import PAD_SEG

NEG_INF = -1e30  # large-negative instead of -inf: keeps softmax NaN-free
                 # for rows that are fully masked (padding)


def dag_attention_allowed(seg_id: jnp.ndarray, layer_id: jnp.ndarray) -> jnp.ndarray:
    """Boolean (..., S, S) 'may attend' matrix from per-token metadata.

    allowed[i, j] = (j <= i in packed order)
                  AND NOT (layer(i) == layer(j) AND seg(i) != seg(j))
                  AND both i, j are real (non-pad) tokens.
    """
    s = seg_id.shape[-1]
    idx = jnp.arange(s)
    causal = idx[None, :] <= idx[:, None]                       # (S, S)
    same_layer = layer_id[..., :, None] == layer_id[..., None, :]
    same_seg = seg_id[..., :, None] == seg_id[..., None, :]
    exclusion = same_layer & ~same_seg
    valid = (seg_id != PAD_SEG)
    pair_valid = valid[..., :, None] & valid[..., None, :]
    return causal & ~exclusion & pair_valid


def ancestor_attention_allowed(
    seg_id: jnp.ndarray, seg_visible: jnp.ndarray
) -> jnp.ndarray:
    """Strict ancestor mask: allowed[i, j] = visible[seg(i), seg(j)] and
    causal-within-segment ordering. ``seg_visible`` is (n_seg, n_seg) bool
    with visible[s, s] True; cross-segment visibility already implies the
    producing segment completed, so full access is causal by construction.
    """
    s = seg_id.shape[-1]
    idx = jnp.arange(s)
    causal = idx[None, :] <= idx[:, None]
    valid = seg_id != PAD_SEG
    safe_seg = jnp.where(valid, seg_id, 0)
    vis = seg_visible[safe_seg[..., :, None], safe_seg[..., None, :]]
    pair_valid = valid[..., :, None] & valid[..., None, :]
    return causal & vis & pair_valid


def mask_bias(allowed: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """Convert a boolean allowed-matrix into an additive attention bias."""
    return jnp.where(allowed, jnp.array(0.0, dtype), jnp.array(NEG_INF, dtype))


def sliding_window_allowed(pos_id: jnp.ndarray, window: int) -> jnp.ndarray:
    """Sliding-window constraint in *adaptive position* space: token i may
    attend to j only if pos(i) - pos(j) < window. Composes (AND) with the
    DAG mask for gemma3/recurrentgemma local layers."""
    diff = pos_id[..., :, None] - pos_id[..., None, :]
    return (diff >= 0) & (diff < window)


def decode_visibility(
    kv_seg_id: jnp.ndarray,
    kv_pos_id: jnp.ndarray,
    q_seg: jnp.ndarray,
    q_pos: jnp.ndarray,
    seg_visible: jnp.ndarray,
) -> jnp.ndarray:
    """Per-stream decode mask: a decoding stream (one query token) sees
    exactly the KV entries of its ancestor segments — the engine's branch
    chain. Shapes: kv_* (..., S); q_seg/q_pos (...,) scalars per stream.
    Used by the serve-step reference path and the decode kernel oracle."""
    valid = kv_seg_id != PAD_SEG
    safe = jnp.where(valid, kv_seg_id, 0)
    vis = seg_visible[q_seg[..., None], safe]
    in_past = kv_pos_id <= q_pos[..., None]
    return vis & in_past & valid
