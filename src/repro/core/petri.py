"""Colored Petri Net execution model (paper Sec. 3.2-3.3).

``N = (P, T, F, M0)``: places hold colored tokens ``tau = (h, k)`` where
``h`` is the textual history of the path and ``k`` the KV-cache reference
(engine-level handle — page ids / radix node). Transitions are reasoning
steps; a transition is *enabled* when every input place holds a token and
every output place is empty (each step fires exactly once).

This module is host-side scheduling logic: it never touches jax. The
engine binds ``k`` to real KV pages; tests bind it to strings.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Mapping, Optional, Sequence, Tuple

from .dag import ReasoningDAG


@dataclasses.dataclass
class ColoredToken:
    """Semantic token tau = (h, k). ``h``: textual history; ``k``: KV ref."""

    history: str
    kv_ref: object = None


@dataclasses.dataclass(frozen=True)
class Transition:
    """A reasoning step t with pre-set (input places) and post-set."""

    tid: int
    label: str
    pre: Tuple[int, ...]   # input place ids
    post: Tuple[int, ...]  # output place ids
    stage: str = "reason"  # "reason" | "critic" | "guardrail"


@dataclasses.dataclass
class PetriNet:
    """N = (P, T, F, M0) built from a transition-level reasoning DAG.

    Construction maps the DAG as the paper does: each transition t_i gets
    one *output place* p_i; an edge (t_j -> t_i) in the DAG wires p_j into
    pre(t_i). DAG source transitions read from a distinguished *context
    place* p_ctx (id 0) holding the prompt+plan token in M0.
    """

    places: Tuple[int, ...]
    transitions: Tuple[Transition, ...]
    ctx_place: int = 0

    @staticmethod
    def from_dag(dag: ReasoningDAG, labels: Optional[Mapping[int, str]] = None) -> "PetriNet":
        labels = labels or {}
        ctx = 0
        place_of = {t: t + 1 for t in dag.nodes}  # output place per transition
        transitions = []
        for t in dag.nodes:
            preds = dag.predecessors(t)
            pre = tuple(place_of[p] for p in preds) if preds else (ctx,)
            transitions.append(
                Transition(
                    tid=t,
                    label=labels.get(t, f"step_{t}"),
                    pre=pre,
                    post=(place_of[t],),
                    stage=dag.stage_of(t),
                )
            )
        places = (ctx,) + tuple(place_of[t] for t in dag.nodes)
        return PetriNet(places=places, transitions=tuple(transitions))

    def transition(self, tid: int) -> Transition:
        for t in self.transitions:
            if t.tid == tid:
                return t
        raise KeyError(tid)


@dataclasses.dataclass
class Marking:
    """Current token assignment M_k: place id -> ColoredToken or None."""

    tokens: Dict[int, Optional[ColoredToken]]

    @staticmethod
    def initial(net: PetriNet, ctx_token: ColoredToken) -> "Marking":
        toks: Dict[int, Optional[ColoredToken]] = {p: None for p in net.places}
        toks[net.ctx_place] = ctx_token
        return Marking(tokens=toks)

    def has(self, place: int) -> bool:
        return self.tokens.get(place) is not None

    def get(self, place: int) -> ColoredToken:
        tok = self.tokens[place]
        assert tok is not None, f"place {place} is empty"
        return tok


@dataclasses.dataclass
class FiredTransition:
    """Record of one firing: which transition, its input tokens, mode."""

    transition: Transition
    inputs: Tuple[ColoredToken, ...]
    mode: str  # "fork" | "join" | "seq"


class PetriScheduler:
    """Frontier-based scheduler implementing Eq. 1 and the execution loop.

    The scheduler is deliberately deterministic (sorted tids) so that runs
    are reproducible; the *engine* decides how many of the frontier's
    transitions actually decode concurrently (continuous batching).
    """

    def __init__(self, net: PetriNet, ctx_token: ColoredToken):
        self.net = net
        self.marking = Marking.initial(net, ctx_token)
        self._fired: set = set()
        self._claimed: set = set()
        self.history: List[List[int]] = []  # frontier tids per step k

    # -- Eq. 1: enabled-transition frontier ---------------------------------
    def frontier(self) -> List[Transition]:
        out = []
        for t in sorted(self.net.transitions, key=lambda t: t.tid):
            if t.tid in self._fired:
                continue
            if all(self.marking.has(p) for p in t.pre) and all(
                not self.marking.has(q) for q in t.post
            ):
                out.append(t)
        return out

    # -- per-transition marking advance (async-frontier engine path) --------
    def ready(self) -> List[Transition]:
        """Enabled transitions not yet claimed for execution.

        The engine claims a transition when it spawns the decode stream
        for it; the transition fires later, when the stream finishes.
        The synchronized path claims whole frontiers at the barrier; the
        async path calls ``ready()`` after every individual ``fire`` so a
        step's successors launch as soon as their own predecessors are
        done, without waiting for unrelated frontier siblings.
        """
        return [t for t in self.frontier() if t.tid not in self._claimed]

    def claim(self, t: Transition) -> None:
        self._claimed.add(t.tid)

    def unblock_count(self, t: Transition) -> int:
        """How many unfired, unclaimed transitions become enabled the
        moment ``t`` fires — i.e. successors of ``t`` whose every *other*
        input place is already marked. This is the frontier-unblocking
        count the stage-aware engine uses to prioritize a ready critic
        whose verdict gates multiple sibling branches."""
        post = set(t.post)
        n = 0
        for u in self.net.transitions:
            if (u.tid == t.tid or u.tid in self._fired
                    or u.tid in self._claimed):
                continue
            if not post & set(u.pre):
                continue
            if all(self.marking.has(p) for p in u.pre if p not in post):
                n += 1
        return n

    def classify_mode(self, t: Transition, frontier: Optional[Sequence[Transition]] = None) -> str:
        """Fork if it shares a predecessor place with another transition in
        the same frontier (common prefix context), Join if it has multiple
        predecessors, else sequential. ``frontier`` defaults to the current
        frontier snapshot."""
        if len(t.pre) > 1:
            return "join"
        if frontier is None:
            frontier = self.frontier()
        siblings = [
            u for u in frontier if u.tid != t.tid and set(u.pre) & set(t.pre)
        ]
        return "fork" if siblings else "seq"

    def fire(self, t: Transition, output_token: ColoredToken,
             mode: Optional[str] = None) -> FiredTransition:
        inputs = tuple(self.marking.get(p) for p in t.pre)
        if mode is None:
            mode = self.classify_mode(t)
        for q in t.post:
            assert not self.marking.has(q), f"output place {q} occupied"
            self.marking.tokens[q] = output_token
        self._fired.add(t.tid)
        return FiredTransition(transition=t, inputs=inputs, mode=mode)

    def step(self, execute) -> List[FiredTransition]:
        """One scheduling-execution cycle: fire the whole frontier via
        ``execute(transition, input_tokens) -> ColoredToken``. Returns the
        fired records; empty list means the net is exhausted."""
        front = self.frontier()
        if not front:
            return []
        self.history.append([t.tid for t in front])
        modes = {t.tid: self.classify_mode(t, front) for t in front}
        fired = []
        for t in front:  # engine executes these concurrently; semantics here
            inputs = tuple(self.marking.get(p) for p in t.pre)
            out = execute(t, inputs)
            fired.append(self.fire(t, out, mode=modes[t.tid]))
        return fired

    def run(self, execute, max_steps: int = 10_000) -> List[List[FiredTransition]]:
        rounds = []
        for _ in range(max_steps):
            fired = self.step(execute)
            if not fired:
                break
            rounds.append(fired)
        return rounds

    def is_complete(self) -> bool:
        return len(self._fired) == len(self.net.transitions)

    def frontier_layers(self) -> List[List[int]]:
        """The realized layering M_0 -> M_1 -> ... (matches
        ReasoningDAG.topological_layers under max-parallel firing)."""
        return [list(l) for l in self.history]
