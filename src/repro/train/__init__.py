from .checkpoint import load_checkpoint, save_checkpoint
from .loss import batch_topo, loss_fn, make_train_step, masked_ce
from .optimizer import AdamWConfig, adamw_update, init_opt_state, lr_schedule

__all__ = [
    "TrainConfig",
    "train_model",
    "load_checkpoint",
    "save_checkpoint",
    "batch_topo",
    "loss_fn",
    "make_train_step",
    "masked_ce",
    "AdamWConfig",
    "adamw_update",
    "init_opt_state",
    "lr_schedule",
]
from .trainer import TrainConfig, train_model
