"""Training loop: curated corpus -> trained MedVerse model (CPU-scale
here; the pjit path in launch/train.py scales the same step function to
the production mesh)."""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..data.dataset import Corpus, encode_example, make_batches
from ..models import init_params
from ..models.config import ModelConfig
from .loss import make_train_step
from .optimizer import AdamWConfig, init_opt_state


@dataclasses.dataclass
class TrainConfig:
    epochs: int = 3                 # paper Sec. 5.1: 3 epochs
    batch_size: int = 8
    seq_len: int = 256
    learning_rate: float = 1e-3     # word-level small models train hot
    log_every: int = 20
    causal: bool = False            # False -> MedVerse attention (Mask-*)
    seed: int = 0
    max_examples: Optional[int] = None


def train_model(cfg: ModelConfig, corpus: Corpus, tcfg: TrainConfig,
                params=None) -> Tuple[dict, List[Dict[str, float]]]:
    tok = corpus.tokenizer
    assert tok.vocab_size <= cfg.vocab_size, (
        f"tokenizer vocab {tok.vocab_size} exceeds model vocab "
        f"{cfg.vocab_size}")
    examples = corpus.train
    if tcfg.max_examples:
        examples = examples[: tcfg.max_examples]
    encoded = [encode_example(e, tok, causal=tcfg.causal) for e in examples]
    if params is None:
        params = init_params(jax.random.PRNGKey(tcfg.seed), cfg)
    total_steps = max(tcfg.epochs * (len(encoded) // tcfg.batch_size), 1)
    opt_cfg = AdamWConfig(
        learning_rate=tcfg.learning_rate,
        warmup_steps=min(20, max(total_steps // 10, 1)),
        total_steps=total_steps,
    )
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    history: List[Dict[str, float]] = []
    it = 0
    for epoch in range(tcfg.epochs):
        batches = make_batches(encoded, tcfg.batch_size, tcfg.seq_len,
                               seed=tcfg.seed + epoch)
        for batch in batches:
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, jb)
            if it % tcfg.log_every == 0:
                rec = {"step": it, "epoch": epoch,
                       "loss": float(metrics["loss"]),
                       "ce": float(metrics["ce"]),
                       "dt": time.time() - t0}
                history.append(rec)
            it += 1
    if history:
        history.append({"step": it, "epoch": tcfg.epochs,
                        "loss": history[-1]["loss"],
                        "ce": history[-1]["ce"], "dt": 0.0})
    return params, history
