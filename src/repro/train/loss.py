"""DAG-masked cross-entropy loss + the jit-able train_step factory."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import TopoBatch, forward, forward_with_hidden, mtp_forward
from ..models.config import ModelConfig
from .optimizer import AdamWConfig, adamw_update


import os

# Sharded-CE (§Perf iteration): take_along_axis over a vocab-sharded
# logits tensor forces XLA to all-gather the logits; the one-hot
# contraction keeps the reduction local per vocab shard and all-reduces
# only a (B, S) scalar field. Toggle to measure both (dryrun --sharded-ce).
_SHARDED_CE = os.environ.get("REPRO_SHARDED_CE", "0") == "1"


def masked_ce(logits: jnp.ndarray, targets: jnp.ndarray,
              mask: jnp.ndarray) -> jnp.ndarray:
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    if _SHARDED_CE:
        onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=lp.dtype)
        nll = -jnp.einsum("...v,...v->...", lp, onehot)
    else:
        nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def batch_topo(batch: Dict[str, jnp.ndarray]) -> TopoBatch:
    return TopoBatch(
        seg_id=batch["seg_id"],
        layer_id=batch["layer_id"],
        pos_id=batch["pos_id"],
        seg_visible=batch.get("seg_visible"),
    )


def loss_fn(params: Any, batch: Dict[str, jnp.ndarray], cfg: ModelConfig
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    topo = batch_topo(batch)
    extra = {}
    if cfg.vision is not None and "image_embeds" in batch:
        extra["image_embeds"] = batch["image_embeds"]
    if cfg.encoder is not None and "audio_embeds" in batch:
        extra["audio_embeds"] = batch["audio_embeds"]
    if cfg.mtp_depth > 0:
        logits, aux, h_final = forward_with_hidden(
            params, batch["tokens"], topo, cfg, **extra)
        ce = masked_ce(logits, batch["targets"], batch["loss_mask"])
        mtp_logits = mtp_forward(params, batch["tokens"], h_final, topo, cfg)
        # mtp predicts t+2: logits index i corresponds to target index i+1
        mtp_ce = masked_ce(
            mtp_logits[:, :-1],
            batch["targets"][:, 2:],
            batch["loss_mask"][:, 2:],
        )
        total = ce + 0.3 * mtp_ce + aux
        return total, {"ce": ce, "mtp_ce": mtp_ce, "aux": aux}
    logits, aux = forward(params, batch["tokens"], topo, cfg, **extra)
    ce = masked_ce(logits, batch["targets"], batch["loss_mask"])
    return ce + aux, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, opt_cfg: Optional[AdamWConfig] = None):
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step
