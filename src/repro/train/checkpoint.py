"""Checkpointing: pytrees serialized with msgpack (+ numpy buffers).

No orbax in this container; this is a self-contained, deterministic
format with shape/dtype manifests and atomic rename on save.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree: Any):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    entries = []
    for path, leaf in flat[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        entries.append((key, leaf))
    return entries, flat[1]


def save_checkpoint(path: str, tree: Any, step: int = 0,
                    metadata: Dict | None = None) -> None:
    entries, _ = _flatten_with_paths(tree)
    payload = {
        "step": step,
        "metadata": metadata or {},
        "tensors": {
            key: {
                "dtype": str(np.asarray(leaf).dtype),
                "shape": list(np.asarray(leaf).shape),
                "data": np.ascontiguousarray(
                    np.asarray(leaf)
                ).tobytes(),
            }
            for key, leaf in entries
        },
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    with os.fdopen(fd, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def load_checkpoint(path: str, like: Any) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``like`` (a pytree template)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    tensors = payload["tensors"]
    entries, tdef = _flatten_with_paths(like)
    leaves = []
    for key, leaf in entries:
        if key not in tensors:
            raise KeyError(f"checkpoint missing tensor '{key}'")
        rec = tensors[key]
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"]))
        arr = arr.reshape(rec["shape"])
        want = np.asarray(leaf)
        if list(arr.shape) != list(want.shape):
            raise ValueError(
                f"shape mismatch for '{key}': ckpt {arr.shape} vs model {want.shape}"
            )
        leaves.append(jnp.asarray(arr))
    tree = jax.tree_util.tree_unflatten(tdef, leaves)
    return tree, payload["step"], payload.get("metadata", {})
