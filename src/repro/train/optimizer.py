"""AdamW + cosine schedule with linear warmup, as a pure pytree
transformation (no optax dependency in this container)."""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 1e-5          # paper Sec. 5.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


def init_opt_state(params: Any) -> dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_schedule(step: jnp.ndarray, cfg: AdamWConfig) -> jnp.ndarray:
    step_f = step.astype(jnp.float32)
    warm = step_f / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    progress = jnp.clip((step_f - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    decayed = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.learning_rate * jnp.where(step_f < cfg.warmup_steps, warm, decayed)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    params: Any, grads: Any, state: dict, cfg: AdamWConfig
) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mu_hat = mu / bc1
        nu_hat = nu / bc2
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(state["mu"])
    flat_nu = jax.tree_util.tree_leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
