"""Live metrics endpoint: a stdlib ``http.server`` thread exposing the
engine's :class:`~repro.obs.metrics.MetricsRegistry` while it serves.

Two routes:

* ``GET /metrics`` — Prometheus text exposition (the registry's
  ``to_prom_text()``: cost counters, compile counters, the chain/page
  bucket histograms, KV page gauges, ...). The render callback runs per
  scrape, so the response always reflects the engine's current plain-int
  counters — no sampling thread, no hot-path cost between scrapes.
* ``GET /healthz`` — ``ok`` (liveness).

Anything else is a 404. The server binds ``127.0.0.1`` by default and
daemonizes its thread, so an exiting process never hangs on it. Wired
into ``serve.py --metrics-port``; usable standalone::

    srv = MetricsServer(lambda: eng.metrics_registry().to_prom_text(),
                        port=9095)
    srv.start()
    ...
    srv.close()

``port=0`` binds an ephemeral port (tests); read it back from
``srv.port`` after ``start()``.
"""

from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable

#: Prometheus text exposition content type (text format 0.0.4).
PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class MetricsServer:
    """Background HTTP server for ``/metrics`` + ``/healthz``."""

    def __init__(self, render: Callable[[], str],
                 host: str = "127.0.0.1", port: int = 0):
        self._render = render
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (http.server API)
                if self.path == "/metrics":
                    try:
                        body = outer._render().encode()
                    except Exception as e:  # render must not kill serving
                        self.send_error(500, str(e))
                        return
                    self.send_response(200)
                    self.send_header("Content-Type", PROM_CONTENT_TYPE)
                    self.send_header("Content-Length", str(len(body)))
                    self.end_headers()
                    self.wfile.write(body)
                elif self.path == "/healthz":
                    body = b"ok\n"
                    self.send_response(200)
                    self.send_header("Content-Type", "text/plain")
                    self.send_header("Content-Length", str(len(body)))
                    self.end_headers()
                    self.wfile.write(body)
                else:
                    self.send_error(404, "unknown path (try /metrics)")

            def log_message(self, fmt, *args):  # silence per-request logs
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="metrics-server",
            daemon=True)

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def address(self) -> str:
        host, port = self._httpd.server_address[:2]
        return f"http://{host}:{port}"

    def start(self) -> "MetricsServer":
        self._thread.start()
        return self

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)
