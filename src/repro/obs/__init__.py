"""Observability subsystem: structured tracing (two clocks — wall
seconds + deterministic engine step), a metrics registry with
Prometheus/JSON export, and per-request DAG timeline summaries.

See ``docs/ARCHITECTURE.md`` ("Observability") for the event taxonomy
and how to open a trace in Perfetto. The default recorder is a no-op
(:data:`NULL_RECORDER`); ``EngineConfig.trace`` / ``serve.py --trace``
turn recording on.
"""

from .audit import (AUDIT_SCHEMA, DECISION_STAGES, DISPOSITIONS,
                    VERDICT_STATUSES, AuditRecord, AuditReport, AuditTrail,
                    Verdict, load_audit_jsonl, rule_verdict)
from .cost import (COST_FIELDS, COST_PHASES, CompileWatcher, CostGeometry,
                   CostLedger)
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      percentile_summary)
from .server import PROM_CONTENT_TYPE, MetricsServer
from .timeline import (RequestTimeline, StreamTimeline, request_timelines,
                       summarize)
from .trace import (NULL_RECORDER, SCHEMA, NullRecorder, TraceRecorder,
                    load_jsonl, to_chrome, validate_spans)

__all__ = [
    "AUDIT_SCHEMA",
    "AuditRecord",
    "AuditReport",
    "AuditTrail",
    "COST_FIELDS",
    "COST_PHASES",
    "CompileWatcher",
    "CostGeometry",
    "CostLedger",
    "Counter",
    "DECISION_STAGES",
    "DISPOSITIONS",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "MetricsServer",
    "NULL_RECORDER",
    "NullRecorder",
    "PROM_CONTENT_TYPE",
    "RequestTimeline",
    "SCHEMA",
    "StreamTimeline",
    "TraceRecorder",
    "VERDICT_STATUSES",
    "Verdict",
    "load_audit_jsonl",
    "load_jsonl",
    "percentile_summary",
    "request_timelines",
    "rule_verdict",
    "summarize",
    "to_chrome",
    "validate_spans",
]
