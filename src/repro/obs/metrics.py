"""Metrics registry: counters, gauges, and histograms with a
Prometheus-text-format dump and a JSON snapshot.

The engine's hot-path components keep their own plain-int counters
(``PageAllocator.stats()``, ``RadixTree`` hit/miss/insert/evict,
``MedVerseEngine.spec_stats`` — incrementing a Python int is the
cheapest thing we can do per event); the registry is populated from
them *at snapshot time* (``MedVerseEngine.metrics_registry``), so
observability never adds work to the decode loop. The registry is also
usable standalone for code that wants to own its metrics directly.

``to_prom_text()`` renders the Prometheus text exposition format
(``# HELP`` / ``# TYPE`` / sample lines, histograms as cumulative
``_bucket{le=...}`` series); ``snapshot()`` returns a JSON-ready dict
that the serving layer merges into ``ServingReport`` (the ``engine``
field), so every serving bench run ships its engine telemetry.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence


def _fmt(v: float) -> str:
    if isinstance(v, float) and math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if isinstance(v, float) and v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


@dataclasses.dataclass
class Counter:
    """Monotonically increasing count."""

    name: str
    help: str = ""
    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        assert n >= 0, f"counter {self.name} decremented"
        self.value += n

    def snapshot(self):
        return self.value


@dataclasses.dataclass
class Gauge:
    """Point-in-time value (may go up or down)."""

    name: str
    help: str = ""
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n

    def snapshot(self):
        return self.value


class Histogram:
    """Fixed-bucket histogram (cumulative on export, Prometheus-style).

    ``buckets`` are the upper bounds of each bin; an implicit ``+Inf``
    bin catches the rest. ``observe(v, n)`` adds ``n`` occurrences of
    value ``v`` (``n`` lets pre-aggregated engine histograms — e.g. the
    chain-bucket histogram, a dict of bucket → step count — load in one
    pass)."""

    def __init__(self, name: str, buckets: Sequence[float], help: str = ""):
        assert buckets and list(buckets) == sorted(buckets)
        self.name = name
        self.help = help
        self.buckets = [float(b) for b in buckets]
        self.counts = [0] * (len(self.buckets) + 1)   # last = +Inf
        self.total = 0
        self.sum = 0.0

    def observe(self, v: float, n: int = 1) -> None:
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += n
                break
        else:
            self.counts[-1] += n
        self.total += n
        self.sum += v * n

    def snapshot(self):
        return {"buckets": {_fmt(b): c for b, c in
                            zip(self.buckets + [float("inf")], self.counts)},
                "count": self.total, "sum": self.sum}


class MetricsRegistry:
    """Name-keyed collection of metrics; get-or-create accessors so
    instrumentation sites stay one-liners."""

    def __init__(self, prefix: str = ""):
        self.prefix = prefix
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, factory):
        name = self.prefix + name
        m = self._metrics.get(name)
        if m is None:
            m = factory(name)
            self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        m = self._get(name, lambda n: Counter(n, help))
        assert isinstance(m, Counter), f"{name} already a {type(m).__name__}"
        return m

    def gauge(self, name: str, help: str = "") -> Gauge:
        m = self._get(name, lambda n: Gauge(n, help))
        assert isinstance(m, Gauge), f"{name} already a {type(m).__name__}"
        return m

    def histogram(self, name: str, buckets: Sequence[float],
                  help: str = "") -> Histogram:
        m = self._get(name, lambda n: Histogram(n, buckets, help))
        assert isinstance(m, Histogram), (
            f"{name} already a {type(m).__name__}")
        return m

    def names(self) -> List[str]:
        return sorted(self._metrics)

    # ------------------------------------------------------------ export --
    def snapshot(self) -> dict:
        """JSON-ready ``{name: value-or-histogram-dict}``."""
        return {name: self._metrics[name].snapshot()
                for name in sorted(self._metrics)}

    def to_prom_text(self) -> str:
        """Prometheus text exposition format."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {name} counter")
                lines.append(f"{name} {_fmt(m.value)}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {name} gauge")
                lines.append(f"{name} {_fmt(m.value)}")
            else:
                assert isinstance(m, Histogram)
                lines.append(f"# TYPE {name} histogram")
                cum = 0
                for b, c in zip(m.buckets + [float("inf")], m.counts):
                    cum += c
                    lines.append(
                        f'{name}_bucket{{le="{_fmt(b)}"}} {cum}')
                lines.append(f"{name}_sum {_fmt(m.sum)}")
                lines.append(f"{name}_count {m.total}")
        return "\n".join(lines) + "\n"


def percentile_summary(xs: Sequence[float],
                       pcts: Sequence[float] = (50, 95, 99)) -> Optional[dict]:
    """Small helper for SLA tails: ``{"p50": ..., "p95": ..., "p99":
    ...}`` or None on empty input (no numpy dependency here — the
    serving layer has its own numpy-based aggregation)."""
    xs = sorted(x for x in xs if not math.isnan(x))
    if not xs:
        return None
    out = {}
    for p in pcts:
        k = (len(xs) - 1) * p / 100.0
        lo, hi = int(math.floor(k)), int(math.ceil(k))
        out[f"p{int(p)}"] = xs[lo] + (xs[hi] - xs[lo]) * (k - lo)
    return out
