"""Analytic cost accounting for the MedVerse engine.

The trace layer (``trace.py``) records *when* things happen; this module
records *what they cost* — computed from engine-native quantities only
(chain lengths, bucket widths, page runs, GQA head geometry), never from
device measurements, so every number is a machine-independent integer
that ``benchmarks/check_regression.py`` can gate **exactly** on the
smoke workload.

Model
-----

Work is counted in **(query, key) pair visits summed over layers** (the
unit both attention FLOPs and KV reads are linear in). With ``H`` query
heads, ``K`` KV heads, head dim ``D`` and per-token KV footprint
``2*K*D*itemsize`` bytes per layer:

* ``attn_flops = 4*H*D * pairs`` — QK^T plus AV matmul FLOPs (the
  softmax itself is O(pairs) and omitted, as is the MLP: the paged KV
  path is what the engine's scheduling decisions change).
* ``kv_read_bytes = 2*K*D*itemsize * pairs`` — K and V streamed from
  the paged pool (decode only; prefill attends over in-flight
  activations, so its pool reads are 0).
* ``kv_write_bytes = 2*K*D*itemsize * n_layers`` per token actually
  written (decode: every batched row; prefill: only the non-cached
  positions ``[m, n)`` — radix hits show up here as saved writes).

Per decode step the *computed* pairs follow the dispatched schedule:

* dense backend: every one of the ``max_slots`` batch rows (including
  padding rows) gathers and attends over the full ``s_bucket`` chain
  width, per layer;
* pallas backend: each real row streams its whole page run
  (``n_pages * page_size`` positions); padding rows have no valid pages
  and are skipped by the kernel.

*Useful* pairs are the positions a row's mask actually exposes
(``min(visible, window)`` per layer); ``padded_kv = computed - useful``
is the padding waste the bucket ladder pays for its bounded compile
count, and ``padded_rows`` counts batch rows carrying no stream.
Prefill computes the full ``bucket x bucket`` score matrix per layer
(the dense reference schedule; the chunked Pallas kernel computes at
most this), useful is the causal lower triangle over the ``n`` prompt
tokens.

Every quantity is attributed to a **phase** — ``prefill`` /
``decode`` (row 0 of each stream's block) / ``spec_verify`` (draft and
extra forced rows) — and to the owning request. Totals land in the
engine's :class:`~repro.obs.metrics.MetricsRegistry` (snapshot time,
zero hot-path cost beyond plain-int adds) and, when tracing is on, in
Perfetto counter tracks (cumulative, one sample per decode step /
prefill) plus a per-request summary on the ``request`` end event.

:class:`CompileWatcher` is the compile-observability half: the engine
notes the static shape key of every jitted dispatch (prefill bucket;
chain bucket for dense decode, page-table bucket for pallas). A key's
first use is a ``compile`` X-span in the trace, and any first use after
``warmup()`` finished increments ``recompiles_after_warmup`` — the
bucket-ladder invariant ("no request hits XLA mid-generation") as a
counter CI gates to zero. Keys are tracked per engine, which makes the
counter deterministic and machine-independent (the process-global XLA
jit cache is not: a second engine in the same process would hit it).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

#: Cost attribution phases, in reporting order.
COST_PHASES = ("prefill", "decode", "spec_verify")

#: Integer fields accumulated per phase (see module docstring).
COST_FIELDS = ("steps", "rows", "attn_flops", "kv_read_bytes",
               "kv_write_bytes", "page_gathers", "useful_kv", "padded_kv",
               "padded_rows")

_DTYPE_BYTES = {"float64": 8, "float32": 4, "float16": 2, "bfloat16": 2,
                "int8": 1, "uint8": 1}


@dataclasses.dataclass(frozen=True)
class CostGeometry:
    """Immutable geometry the analytic formulas need: GQA head layout,
    per-layer attention windows (0 = global), KV dtype width, and the
    engine's batch/page shape."""

    n_heads: int
    n_kv_heads: int
    head_dim: int
    windows: Tuple[int, ...]      # per layer; 0 = global attention
    dtype_bytes: int
    page_size: int
    max_slots: int

    @classmethod
    def from_model(cls, cfg, page_size: int, max_slots: int,
                   dtype: Optional[str] = None) -> "CostGeometry":
        from ..models.config import ATTN, LOCAL_ATTN
        windows = []
        for kind in cfg.layer_kinds:
            if kind == ATTN:
                windows.append(0)
            elif kind == LOCAL_ATTN:
                windows.append(int(cfg.sliding_window))
            # non-attention layers hold no paged KV (the engine asserts
            # supports_paged, so this branch is future-proofing only)
        return cls(
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, windows=tuple(windows),
            dtype_bytes=_DTYPE_BYTES.get(str(dtype or cfg.dtype), 4),
            page_size=page_size, max_slots=max_slots)

    @property
    def n_layers(self) -> int:
        return len(self.windows)

    @property
    def flops_per_pair(self) -> int:
        """QK^T + AV matmul FLOPs per (query, key) pair per layer."""
        return 4 * self.n_heads * self.head_dim

    @property
    def kv_bytes_per_pair(self) -> int:
        """K + V bytes read per (query, key) pair per layer."""
        return 2 * self.n_kv_heads * self.head_dim * self.dtype_bytes

    @property
    def kv_token_write_bytes(self) -> int:
        """K + V bytes written per token across all layers."""
        return self.n_layers * 2 * self.n_kv_heads * self.head_dim \
            * self.dtype_bytes

    def useful_pairs(self, visible: int) -> int:
        """Mask-exposed pairs for one query row over ``visible`` KV
        positions, summed over layers (local layers clamp to their
        window)."""
        return sum(min(visible, w) if w else visible
                   for w in self.windows)

    def causal_pairs(self, n: int) -> int:
        """Causal lower-triangle pairs over an ``n``-token prefix,
        summed over layers."""
        total = 0
        for w in self.windows:
            if w and w < n:
                # first w rows are triangular, the rest see w positions
                total += w * (w + 1) // 2 + (n - w) * w
            else:
                total += n * (n + 1) // 2
        return total


class CostLedger:
    """Per-phase / per-request accumulator over :class:`CostGeometry`.

    The engine calls :meth:`note_prefill` once per prompt prefill and
    :meth:`note_decode` once per batched decode step; both are pure
    Python-int arithmetic over values the hot path already holds, so
    cost accounting is passive — it never touches the schedule, RNG, or
    page accounting (pinned by ``tests/test_cost.py``).
    """

    def __init__(self, geom: CostGeometry):
        self.geom = geom
        self.totals: Dict[str, Dict[str, int]] = {
            ph: {f: 0 for f in COST_FIELDS} for ph in COST_PHASES}
        self.requests: Dict[int, Dict[str, Dict[str, int]]] = {}

    # --------------------------------------------------------- accumulate --
    def _acc(self, rid: Optional[int], phase: str, **fields: int) -> None:
        tot = self.totals[phase]
        for k, v in fields.items():
            tot[k] += v
        if rid is not None:
            per = self.requests.get(rid)
            if per is None:
                per = self.requests[rid] = {
                    ph: {f: 0 for f in COST_FIELDS} for ph in COST_PHASES}
            dst = per[phase]
            for k, v in fields.items():
                dst[k] += v

    def note_prefill(self, rid: Optional[int], n_prompt: int,
                     n_cached: int, bucket: int) -> None:
        """One prompt prefill: full ``bucket x bucket`` score matrix per
        layer computed, causal pairs over the ``n_prompt`` real tokens
        useful, K/V written only for the non-cached ``[m, n)`` span."""
        g = self.geom
        computed = g.n_layers * bucket * bucket
        useful = g.causal_pairs(n_prompt)
        self._acc(
            rid, "prefill", steps=1, rows=n_prompt,
            attn_flops=g.flops_per_pair * computed,
            kv_read_bytes=0,
            kv_write_bytes=(n_prompt - n_cached) * g.kv_token_write_bytes,
            page_gathers=0, useful_kv=useful,
            padded_kv=computed - useful, padded_rows=0)

    def note_decode(self, rows: Sequence[Tuple[Optional[int], int, bool]],
                    s_bucket: int, pages: Sequence[int],
                    backend: str) -> None:
        """One batched decode step.

        ``rows`` is the real (non-padding) batch: ``(rid, visible,
        phase)`` per row, where ``visible`` is the KV length the row's
        position mask exposes and ``phase`` attributes the row — a bool
        (legacy: True marks speculative rows — draft proposals and extra
        forced rows beyond the stream's committed input) or a phase
        string; chunked prefill feeds prompt rows through the decode
        step and attributes them ``"prefill"``. ``pages[i]`` is row i's
        distinct-page count. Dense attends ``s_bucket`` wide for all
        ``max_slots`` batch rows (padding rows included); pallas streams
        each real row's whole page run and skips padding rows.
        """
        g = self.geom
        n = len(rows)
        pad_rows = g.max_slots - n
        spec_seen = False
        for (rid, visible, flag), n_pages in zip(rows, pages):
            if isinstance(flag, str):
                phase = flag
            else:
                phase = "spec_verify" if flag else "decode"
            spec_seen = spec_seen or phase == "spec_verify"
            computed = (g.n_layers * n_pages * g.page_size
                        if backend == "pallas"
                        else g.n_layers * s_bucket)
            useful = g.useful_pairs(visible)
            self._acc(
                rid, phase, rows=1,
                attn_flops=g.flops_per_pair * computed,
                kv_read_bytes=g.kv_bytes_per_pair * computed,
                kv_write_bytes=g.kv_token_write_bytes,
                page_gathers=n_pages, useful_kv=useful,
                padded_kv=computed - useful)
        # batch padding: dense computes (and reads) the full bucket for
        # padding rows too; pallas skips them (no valid pages)
        if pad_rows and backend != "pallas":
            waste = self.geom.n_layers * pad_rows * s_bucket
            self._acc(None, "decode",
                      attn_flops=g.flops_per_pair * waste,
                      kv_read_bytes=g.kv_bytes_per_pair * waste,
                      padded_kv=waste)
        self._acc(None, "decode", steps=1, padded_rows=pad_rows)
        if spec_seen:
            self._acc(None, "spec_verify", steps=1)

    # ------------------------------------------------------------ export ---
    def total(self, field: str) -> int:
        return sum(self.totals[ph][field] for ph in COST_PHASES)

    def padding_waste_ratio(self) -> float:
        """Padded share of all computed (query, key) pairs."""
        computed = self.total("useful_kv") + self.total("padded_kv")
        return self.total("padded_kv") / computed if computed else 0.0

    def emit(self, obs) -> None:
        """Sample the cumulative totals as Perfetto counter tracks
        (called by the engine once per decode step and per prefill, so
        the series are step-indexed and deterministic)."""
        t = self.totals
        obs.counter("cost_attn_flops",
                    {ph: t[ph]["attn_flops"] for ph in COST_PHASES})
        obs.counter("cost_kv_bytes", {"read": self.total("kv_read_bytes"),
                                      "written": self.total("kv_write_bytes")})
        obs.counter("cost_padding", {"useful_kv": self.total("useful_kv"),
                                     "padded_kv": self.total("padded_kv"),
                                     "padded_rows": self.total("padded_rows")})
        obs.counter("cost_pages", {"gathers": self.total("page_gathers")})

    def request_summary(self, rid: int) -> Dict[str, Dict[str, int]]:
        """Per-phase cost dict for one request (attached to its
        ``request`` end event; empty phases included for schema
        stability)."""
        per = self.requests.get(rid)
        if per is None:
            per = {ph: {f: 0 for f in COST_FIELDS} for ph in COST_PHASES}
        return {ph: dict(per[ph]) for ph in COST_PHASES}

    def summary(self) -> Dict[str, int]:
        """Flat lifetime summary, the shape the serving bench records
        (and ``check_regression.py`` gates exactly)."""
        out: Dict[str, int] = {}
        for ph in COST_PHASES:
            out[f"{ph}_attn_flops"] = self.totals[ph]["attn_flops"]
        for f in ("kv_read_bytes", "kv_write_bytes", "page_gathers",
                  "useful_kv", "padded_kv", "padded_rows"):
            out[f] = self.total(f)
        return out

    def register(self, reg) -> None:
        """Load the lifetime totals into a
        :class:`~repro.obs.metrics.MetricsRegistry` (snapshot-time, like
        every other engine counter)."""
        for ph in COST_PHASES:
            for f in COST_FIELDS:
                reg.counter(
                    f"cost_{ph}_{f}_total",
                    f"analytic cost model: lifetime {f} in the {ph} "
                    f"phase").inc(self.totals[ph][f])
        reg.gauge("padding_waste_ratio",
                  "padded share of computed (query, key) attention "
                  "pairs").set(self.padding_waste_ratio())


class CompileWatcher:
    """Engine-level compiled-shape tracking (see module docstring).

    ``note(key)`` returns True the first time a static shape key is
    dispatched — the engine then wraps that call in a ``compile`` X-span
    — and counts first uses after :meth:`finish_warmup` as
    ``recompiles_after_warmup`` (gated to zero on the smoke workload).
    """

    def __init__(self):
        self.seen: set = set()
        self.keys: List[tuple] = []       # first-use order
        self.compiles_total = 0
        self.recompiles_after_warmup = 0
        self.warmup_step: Optional[int] = None

    def note(self, key: tuple) -> bool:
        if key in self.seen:
            return False
        self.seen.add(key)
        self.keys.append(key)
        self.compiles_total += 1
        if self.warmup_step is not None:
            self.recompiles_after_warmup += 1
        return True

    def finish_warmup(self, step: int) -> None:
        """Mark the warmup ladder complete; key first-uses from here on
        are recompiles. Idempotent — re-warming keeps the original
        boundary."""
        if self.warmup_step is None:
            self.warmup_step = int(step)

    def register(self, reg) -> None:
        reg.counter("compiles_total",
                    "distinct compiled shape keys dispatched").inc(
                        self.compiles_total)
        reg.counter("recompiles_after_warmup_total",
                    "shape keys first dispatched after the warmup "
                    "ladder finished (bucket-ladder invariant: 0)").inc(
                        self.recompiles_after_warmup)
