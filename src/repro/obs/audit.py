"""Clinical audit trail: per-decision audit records and request-level
dispositions for stage-typed DAG plans.

Every ``critic`` / ``guardrail`` stream that finishes produces one
:class:`AuditRecord` carrying a :class:`Verdict` (``pass`` | ``fail`` |
``abstain``) extracted by a pluggable, deterministic rule over the
stream's generated body and its predecessors' texts — no judge model,
so verdict counts are CI-gateable at temperature 0. When a request
finishes (or is aborted) the trail closes it with a disposition record
(``verified`` | ``refuted`` | ``unverified``) summarized in an
:class:`AuditReport`.

The trail is strictly *passive*: it only reads decoded text and the
engine's deterministic step clock, never RNG, page accounting, or
scheduling state — temp-0 output is bit-identical with auditing on or
off. Records mirror into the :class:`~repro.obs.trace.TraceRecorder`
as ``cat="audit"`` instants (two-clock: wall ``ts`` + decode ``step``)
and dump standalone as ``medverse-audit/1`` JSONL.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Callable, Dict, List, Optional, Tuple

from .trace import NULL_RECORDER

AUDIT_SCHEMA = "medverse-audit/1"

#: stages that produce a decision record when their stream finishes
DECISION_STAGES = ("critic", "guardrail")
VERDICT_STATUSES = ("pass", "fail", "abstain")
DISPOSITIONS = ("verified", "refuted", "unverified")

# Marker vocabularies for the rule-based extractor: an explicit verdict
# word anywhere in a critic/guardrail body decides the outcome (last
# marker wins — a closing verdict overrides earlier hedging).
PASS_MARKERS = frozenset(
    "confirmed consistent supported verified correct pass passes "
    "safe plausible".split())
FAIL_MARKERS = frozenset(
    "refuted inconsistent contradicted unsupported incorrect fail "
    "fails violation unsafe contraindicated".split())

# Words ignored by the evidence-overlap fallback: structural grammar
# plus connectives that would manufacture spurious grounding.
_STOPWORDS = frozenset(
    "transient step dependency stage outline plan think conclusion "
    "answer explanation the and with from this that then when "
    "assess verify check".split())


def _content_words(text: str) -> List[Tuple[str, int]]:
    """Lowercased alphabetic words of length >= 4 with char offsets."""
    out = []
    pos = 0
    for w in text.split():
        start = text.index(w, pos)
        pos = start + len(w)
        lw = w.lower()
        if len(lw) >= 4 and lw.isalpha() and lw not in _STOPWORDS:
            out.append((lw, start))
    return out


@dataclasses.dataclass(frozen=True)
class Verdict:
    """Outcome of one critic/guardrail decision.

    ``span`` is the (start, end) character range in the stream body that
    grounds the verdict (the deciding marker word or the first shared
    evidence term); ``(-1, -1)`` when nothing specific grounds it.
    """

    status: str                       # "pass" | "fail" | "abstain"
    reason: str                       # human-readable rule explanation
    evidence: str = ""                # the grounding word(s), if any
    span: Tuple[int, int] = (-1, -1)  # char offsets into the body

    def to_dict(self) -> dict:
        return {"status": self.status, "reason": self.reason,
                "evidence": self.evidence, "span": list(self.span)}

    @staticmethod
    def from_dict(d: dict) -> "Verdict":
        return Verdict(status=d["status"], reason=d["reason"],
                       evidence=d.get("evidence", ""),
                       span=tuple(d.get("span", (-1, -1))))


def rule_verdict(body: str, evidence: str = "",
                 min_overlap: int = 2) -> Verdict:
    """Deterministic rule-based verdict extractor (the default).

    Tier 1 — marker scan: an explicit pass/fail word in the body decides
    (last marker wins). Tier 2 — evidence grounding: the body's content
    words are intersected with the predecessors' texts; ``min_overlap``
    shared terms is a pass, a substantive body with zero shared terms is
    a fail (ungrounded critique), anything shorter abstains.
    """
    words = _content_words(body)
    marker = None
    for lw, start in words:
        if lw in PASS_MARKERS:
            marker = ("pass", lw, start)
        elif lw in FAIL_MARKERS:
            marker = ("fail", lw, start)
    if marker is not None:
        status, lw, start = marker
        return Verdict(status=status, reason=f"marker {lw!r}",
                       evidence=lw, span=(start, start + len(lw)))
    ev_words = {lw for lw, _ in _content_words(evidence)}
    shared = [(lw, start) for lw, start in words if lw in ev_words]
    if len(shared) >= min_overlap:
        lw, start = shared[0]
        return Verdict(
            status="pass",
            reason=f"evidence overlap: {len(shared)} shared terms",
            evidence=" ".join(lw for lw, _ in shared),
            span=(start, start + len(lw)))
    if len(words) >= 3:
        return Verdict(status="fail",
                       reason="no evidential overlap with predecessors")
    return Verdict(status="abstain", reason="no verdict marker")


@dataclasses.dataclass(frozen=True)
class AuditRecord:
    """One line of the audit JSONL: a stage decision or a disposition."""

    kind: str                 # "decision" | "disposition"
    rid: int
    step: int                 # deterministic decode-step clock
    node: int = -1            # transition tid (decisions only)
    stage: str = ""           # "critic" | "guardrail" (decisions only)
    verdict: Optional[Verdict] = None        # decisions only
    disposition: str = ""     # dispositions only
    report: Optional["AuditReport"] = None   # dispositions only

    def to_dict(self) -> dict:
        d = {"kind": self.kind, "rid": self.rid, "step": self.step}
        if self.kind == "decision":
            d.update(node=self.node, stage=self.stage,
                     verdict=self.verdict.to_dict())
        else:
            d.update(disposition=self.disposition,
                     report=self.report.to_dict())
        return d

    @staticmethod
    def from_dict(d: dict) -> "AuditRecord":
        if d["kind"] == "decision":
            return AuditRecord(kind="decision", rid=d["rid"],
                               step=d["step"], node=d["node"],
                               stage=d["stage"],
                               verdict=Verdict.from_dict(d["verdict"]))
        return AuditRecord(kind="disposition", rid=d["rid"],
                           step=d["step"], disposition=d["disposition"],
                           report=AuditReport.from_dict(d["report"]))


@dataclasses.dataclass(frozen=True)
class AuditReport:
    """Per-request audit summary, computed when the request closes.

    Disposition: ``verified`` — the request completed, ran at least one
    critic, every critic passed and no guardrail failed; ``refuted`` —
    it completed but a critic or guardrail failed; ``unverified`` —
    everything else (no critics, critic abstained, or the request never
    completed). ``critic_coverage`` is the fraction of critic decisions
    that produced a non-abstain verdict.
    """

    rid: int
    disposition: str
    completed: bool
    n_stage: Dict[str, int]          # stream count per stage
    verdicts: Dict[str, int]         # decision count per verdict status
    critic_coverage: float
    guardrail_violations: int

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "AuditReport":
        return AuditReport(
            rid=d["rid"], disposition=d["disposition"],
            completed=d["completed"], n_stage=dict(d["n_stage"]),
            verdicts=dict(d["verdicts"]),
            critic_coverage=d["critic_coverage"],
            guardrail_violations=d["guardrail_violations"])


class AuditTrail:
    """Consumes stream-end notifications, emits audit records.

    ``extract(body, evidence) -> Verdict`` is pluggable; the default is
    :func:`rule_verdict`. ``obs`` is a :class:`TraceRecorder` (or the
    null recorder) that decision/disposition instants mirror into as
    ``cat="audit"`` events, inside the request's open trace span.
    """

    def __init__(self, extract: Optional[Callable] = None,
                 obs=NULL_RECORDER, meta: Optional[dict] = None):
        self.extract = extract or rule_verdict
        self.obs = obs
        self.meta = dict(meta or {})
        self.records: List[AuditRecord] = []
        self.reports: Dict[int, AuditReport] = {}
        self._live: Dict[int, List[AuditRecord]] = {}   # open decisions
        self._stage_counts: Dict[int, Dict[str, int]] = {}

    # ------------------------------------------------------- ingest ----
    def on_stream_end(self, rid: int, node: int, stage: str, body: str,
                      evidence: str, step: int,
                      track: str = "") -> Optional[AuditRecord]:
        """Notify the trail that a step stream finished. Returns the
        decision record for critic/guardrail stages, None otherwise."""
        counts = self._stage_counts.setdefault(rid, {})
        counts[stage] = counts.get(stage, 0) + 1
        if stage not in DECISION_STAGES:
            return None
        verdict = self.extract(body, evidence)
        rec = AuditRecord(kind="decision", rid=rid, step=step, node=node,
                          stage=stage, verdict=verdict)
        self.records.append(rec)
        self._live.setdefault(rid, []).append(rec)
        if self.obs.enabled:
            self.obs.instant("audit", "audit", rid=rid, track=track,
                             node=node, stage=stage,
                             status=verdict.status, reason=verdict.reason)
        return rec

    def on_preempt(self, rid: int) -> None:
        """The request was evicted and will restart from scratch: drop
        its partial decision records so re-admission does not duplicate
        them (the verdict is deferred to the re-run, which re-decodes
        every stream). No disposition is emitted."""
        dropped = self._live.pop(rid, None)
        if dropped:
            drop = {id(r) for r in dropped}
            self.records = [r for r in self.records if id(r) not in drop]
        self._stage_counts.pop(rid, None)

    def finish_request(self, rid: int, completed: bool,
                       step: int) -> AuditRecord:
        """Close the request with a disposition record (exactly once per
        request lifetime — on completion or abort, never preemption)."""
        decisions = self._live.pop(rid, [])
        n_stage = self._stage_counts.pop(rid, {})
        verdicts = {s: 0 for s in VERDICT_STATUSES}
        for r in decisions:
            verdicts[r.verdict.status] += 1
        critics = [r for r in decisions if r.stage == "critic"]
        violations = sum(1 for r in decisions
                         if r.stage == "guardrail"
                         and r.verdict.status == "fail")
        decided = sum(1 for r in critics if r.verdict.status != "abstain")
        coverage = decided / len(critics) if critics else 0.0
        failed = any(r.verdict.status == "fail" for r in critics)
        if not completed or not critics:
            disposition = "unverified"
        elif failed or violations:
            disposition = "refuted"
        elif decided == len(critics):
            disposition = "verified"
        else:
            disposition = "unverified"   # some critic abstained
        report = AuditReport(
            rid=rid, disposition=disposition, completed=completed,
            n_stage=n_stage, verdicts=verdicts, critic_coverage=coverage,
            guardrail_violations=violations)
        rec = AuditRecord(kind="disposition", rid=rid, step=step,
                          disposition=disposition, report=report)
        self.records.append(rec)
        self.reports[rid] = report
        if self.obs.enabled:
            self.obs.instant("audit_disposition", "audit", rid=rid,
                             disposition=disposition,
                             completed=completed,
                             critic_coverage=coverage,
                             guardrail_violations=violations)
        return rec

    # ------------------------------------------------------ queries ----
    def counts(self) -> Dict[str, int]:
        """Aggregate counters for the metrics registry / bench gates."""
        out = {"records": len(self.records), "decisions": 0,
               "dispositions": 0}
        for s in VERDICT_STATUSES:
            out[f"verdict_{s}"] = 0
        for d in DISPOSITIONS:
            out[d] = 0
        for r in self.records:
            if r.kind == "decision":
                out["decisions"] += 1
                out[f"verdict_{r.verdict.status}"] += 1
            else:
                out["dispositions"] += 1
                out[r.disposition] += 1
        return out

    # ----------------------------------------------------------- io ----
    def dump_jsonl(self, path: str) -> str:
        header = {"schema": AUDIT_SCHEMA, "meta": self.meta}
        with open(path, "w") as f:
            f.write(json.dumps(header) + "\n")
            for r in self.records:
                f.write(json.dumps(r.to_dict()) + "\n")
        return path


def load_audit_jsonl(path: str) -> Tuple[dict, List[AuditRecord]]:
    """Round-trip loader for ``medverse-audit/1`` JSONL files."""
    with open(path) as f:
        header = json.loads(f.readline())
        if header.get("schema") != AUDIT_SCHEMA:
            raise ValueError(
                f"not a {AUDIT_SCHEMA} file: {header.get('schema')!r}")
        records = [AuditRecord.from_dict(json.loads(line))
                   for line in f if line.strip()]
    return header, records
