"""Per-request DAG timelines: quantify the paper's parallelism claim
from a recorded trace.

Built post-hoc from the recorder's ``stream`` spans (one ``B``/``E``
pair per decode stream — plan, each DAG transition, conclusion) and
``first_token`` instants. For every request:

* per-stream ``spawn_step`` / ``first_token_step`` / ``done_step`` on
  the deterministic step clock (plus wall times);
* ``critical_path_steps`` — the request's makespan in decode steps,
  ``max(done) - min(spawn)`` over its streams;
* ``sum_chain_steps`` — what the same work would cost executed one
  stream after another (the serial baseline the paper's 1.3x latency
  claim is against);
* ``parallelism = sum_chain_steps / critical_path_steps`` — realized
  DAG speedup for this request;
* ``max_overlap`` — the widest frontier actually decoding at once
  (>= 2 means the Petri net genuinely ran transitions in parallel,
  the acceptance bar for a traced smoke run).

``summarize`` renders one line per request for CLI output
(``serve.py --trace``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class StreamTimeline:
    track: str                    # "plan" | "t<N>" | "conclusion" | ...
    purpose: str
    tid: int                      # DAG transition id, -1 for non-steps
    spawn_step: int
    done_step: int
    first_token_step: int = -1
    n_tokens: int = 0
    t_spawn: float = 0.0
    t_done: float = 0.0
    # stage-typed DAG streams ("reason" | "critic" | "guardrail"; empty
    # for plan/conclusion) and, when the audit trail was on, the verdict
    # the stream's decision record carried ("pass" | "fail" | "abstain")
    stage: str = ""
    verdict: str = ""

    @property
    def steps(self) -> int:
        return self.done_step - self.spawn_step


@dataclasses.dataclass
class RequestTimeline:
    rid: int
    streams: List[StreamTimeline]
    # final audit disposition ("verified" | "refuted" | "unverified");
    # empty when the trace was recorded without the audit trail
    disposition: str = ""

    @property
    def critical_path_steps(self) -> int:
        if not self.streams:
            return 0
        return (max(s.done_step for s in self.streams)
                - min(s.spawn_step for s in self.streams))

    @property
    def sum_chain_steps(self) -> int:
        return sum(s.steps for s in self.streams)

    @property
    def parallelism(self) -> float:
        crit = self.critical_path_steps
        return self.sum_chain_steps / crit if crit > 0 else 1.0

    @property
    def max_overlap(self) -> int:
        """Max number of this request's streams live on one step."""
        marks = []
        for s in self.streams:
            marks.append((s.spawn_step, 1))
            marks.append((s.done_step, -1))
        # a stream ending exactly where another spawns does not overlap
        marks.sort(key=lambda m: (m[0], m[1]))
        live = peak = 0
        for _, d in marks:
            live += d
            peak = max(peak, live)
        return peak

    def to_dict(self) -> dict:
        return {
            "rid": self.rid,
            "critical_path_steps": self.critical_path_steps,
            "sum_chain_steps": self.sum_chain_steps,
            "parallelism": self.parallelism,
            "max_overlap": self.max_overlap,
            "disposition": self.disposition,
            "streams": [dataclasses.asdict(s) for s in self.streams],
        }


def request_timelines(events: List[dict]) -> Dict[int, RequestTimeline]:
    """Fold a trace's ``stream`` spans into per-request timelines.

    Streams cut short by abort/preemption (their ``E`` carries
    ``aborted=True``) are dropped — the timeline describes committed
    work; a re-admitted request's fresh streams still count."""
    open_streams: Dict[tuple, dict] = {}
    per_rid: Dict[int, List[StreamTimeline]] = {}
    # audit instants arrive after the stream span they describe closes
    # (the engine emits the decision once the stream is done), so they
    # are collected here and attached to the built timelines at the end
    verdicts: Dict[tuple, str] = {}
    dispositions: Dict[int, str] = {}
    for ev in events:
        args = ev.get("args", {})
        if ev.get("cat") == "audit":
            if ev.get("name") == "audit":
                verdicts[(ev.get("rid"), ev.get("track"))] = \
                    args.get("status", "")
            elif ev.get("name") == "audit_disposition":
                dispositions[ev.get("rid")] = args.get("disposition", "")
            continue
        if ev.get("cat") != "stream":
            continue
        key = (ev.get("rid"), ev.get("track"))
        if ev["ph"] == "B" and ev["name"] == "stream":
            open_streams[key] = {
                "spawn_step": ev["step"], "t_spawn": ev["ts"],
                "purpose": args.get("purpose", ""),
                "tid": args.get("tid", -1),
                "stage": args.get("stage", ""),
                "first_token_step": -1,
            }
        elif ev["ph"] == "I" and ev["name"] == "first_token":
            st = open_streams.get(key)
            if st is not None and st["first_token_step"] < 0:
                st["first_token_step"] = ev["step"]
        elif ev["ph"] == "E" and ev["name"] == "stream":
            st = open_streams.pop(key, None)
            if st is None or args.get("aborted"):
                continue
            rid = ev.get("rid")
            per_rid.setdefault(rid, []).append(StreamTimeline(
                track=ev.get("track", ""),
                purpose=st["purpose"], tid=st["tid"],
                spawn_step=st["spawn_step"],
                done_step=ev["step"],
                first_token_step=st["first_token_step"],
                n_tokens=args.get("n_tokens", 0),
                t_spawn=st["t_spawn"], t_done=ev["ts"],
                stage=st["stage"]))
    for rid, streams in per_rid.items():
        for s in streams:
            s.verdict = verdicts.get((rid, s.track), "")
    return {rid: RequestTimeline(rid=rid, streams=streams,
                                 disposition=dispositions.get(rid, ""))
            for rid, streams in sorted(per_rid.items())}


_VERDICT_MARKS = {"pass": "✓", "fail": "✗", "abstain": "?"}


def _stream_tag(s: StreamTimeline) -> str:
    """``t3[12..18]`` plus a ``[critic ✗]``-style stage/verdict suffix
    for decision stages (only rendered when the stream carried one)."""
    tag = f"{s.track}[{s.spawn_step}..{s.done_step}]"
    if s.stage and s.stage != "reason":
        mark = _VERDICT_MARKS.get(s.verdict, "")
        tag += f"[{s.stage} {mark}]" if mark else f"[{s.stage}]"
    return tag


def summarize(events: List[dict],
              timelines: Optional[Dict[int, RequestTimeline]] = None) -> str:
    """One line per request: realized parallelism vs the serial sum."""
    timelines = timelines if timelines is not None \
        else request_timelines(events)
    lines = []
    for rid, tl in sorted(timelines.items()):
        tracks = " ".join(
            _stream_tag(s)
            for s in sorted(tl.streams,
                            key=lambda s: (s.spawn_step, s.track)))
        verified = (f"verified={tl.disposition} "
                    if tl.disposition else "")
        lines.append(
            f"rid={rid} streams={len(tl.streams)} "
            f"critical_path={tl.critical_path_steps}st "
            f"sum_chains={tl.sum_chain_steps}st "
            f"parallelism={tl.parallelism:.2f}x "
            f"max_overlap={tl.max_overlap} {verified}| {tracks}")
    return "\n".join(lines)
