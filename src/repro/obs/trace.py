"""Structured trace recorder for the MedVerse engine.

The engine, memory system, radix cache, speculative-decode path, and
the continuous-batching scheduler all emit events through one recorder
object (``MedVerseEngine.obs``). Three event shapes:

* **span** — a ``B``(egin)/``E``(nd) pair on a *track* (e.g. the
  lifetime of one DAG-transition decode stream), or a single ``X``
  (complete) event carrying its own duration (e.g. one batched
  ``paged_decode`` call);
* **instant** (``I``) — a point event (a page allocation, a radix hit,
  a preemption, one speculative verify);
* **counter** (``C``) — a sampled gauge set (KV page occupancy, queue
  depth) that Perfetto renders as a time series.

Every event carries **two clocks**: ``ts``, wall seconds relative to
recorder start (what an operator reads), and ``step``, the engine's
deterministic decode-iteration counter (what tests and cross-machine
comparisons read — event *counts* and step intervals are bit-stable on
a fixed workload, wall timestamps are not).

The default recorder is :data:`NULL_RECORDER`: ``enabled`` is False and
every hook short-circuits, so an untraced engine pays one attribute
check per instrumented site and allocates nothing. Tracing is passive
either way — it never touches RNG, page accounting, or scheduling, so
temperature-0 output is bit-identical with tracing on or off (pinned by
``tests/test_obs.py``).

Exporters: :meth:`TraceRecorder.dump_jsonl` writes the native schema
(one JSON object per line, header first — validated by
``tools/check_trace.py``); :meth:`TraceRecorder.dump_chrome` writes
Chrome trace-event JSON loadable in Perfetto (https://ui.perfetto.dev),
where each request is a *process* and each DAG transition stream is a
*thread track* — the parallel frontier is visually inspectable.
"""

from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

SCHEMA = "medverse-trace/1"

#: Event phases used in the native schema (a subset of Chrome's).
PHASES = ("B", "E", "I", "X", "C")


class NullRecorder:
    """Disabled recorder: every hook is a no-op returning immediately.

    Instrumented code guards any non-trivial argument construction
    behind ``if obs.enabled:``, so the disabled cost per site is one
    attribute load and (rarely) one no-op call.
    """

    __slots__ = ()
    enabled = False
    step = 0

    def set_step(self, step: int) -> None:
        pass

    def now(self) -> float:
        return 0.0

    def meta(self, **kv) -> None:
        pass

    def begin(self, name, cat="engine", rid=None, track=None, **args):
        pass

    def end(self, name, cat="engine", rid=None, track=None, **args):
        pass

    def instant(self, name, cat="engine", rid=None, track=None, **args):
        pass

    def complete(self, name, cat, t0, rid=None, track=None, **args):
        pass

    def counter(self, name, values, rid=None):
        pass


#: The shared disabled recorder every component defaults to.
NULL_RECORDER = NullRecorder()


class TraceRecorder:
    """In-memory recording implementation of the hook interface.

    Events are plain JSON-ready dicts appended in emission order:
    ``{"ph", "name", "cat", "ts", "step"}`` plus optional ``"rid"``
    (owning request), ``"track"`` (sub-request lane, e.g. ``"plan"`` /
    ``"t3"`` / ``"conclusion"``), ``"dur"`` (``X`` only), ``"args"``
    (event payload) and ``"values"`` (``C`` only).
    """

    enabled = True

    def __init__(self, clock=time.monotonic):
        self._clock = clock
        self.t0 = clock()
        self.step = 0
        self.events: List[dict] = []
        self.meta_args: Dict[str, object] = {}

    # ------------------------------------------------------------ clocks --
    def now(self) -> float:
        """Wall seconds since recorder start."""
        return self._clock() - self.t0

    def set_step(self, step: int) -> None:
        """Advance the deterministic step clock (the engine calls this
        at the top of every ``step()``)."""
        self.step = int(step)

    # ------------------------------------------------------------- emit ---
    def meta(self, **kv) -> None:
        """Attach header metadata (pool geometry, backend, ...)."""
        self.meta_args.update(kv)

    def _ev(self, ph: str, name: str, cat: str, rid, track,
            args: dict, dur: Optional[float] = None,
            values: Optional[dict] = None) -> None:
        ev = {"ph": ph, "name": name, "cat": cat,
              "ts": self.now(), "step": self.step}
        if rid is not None:
            ev["rid"] = int(rid)
        if track is not None:
            ev["track"] = str(track)
        if dur is not None:
            ev["dur"] = dur
        if args:
            ev["args"] = args
        if values is not None:
            ev["values"] = values
        self.events.append(ev)

    def begin(self, name, cat="engine", rid=None, track=None, **args):
        """Open a span on ``(rid, track)``; close with :meth:`end`."""
        self._ev("B", name, cat, rid, track, args)

    def end(self, name, cat="engine", rid=None, track=None, **args):
        self._ev("E", name, cat, rid, track, args)

    def instant(self, name, cat="engine", rid=None, track=None, **args):
        self._ev("I", name, cat, rid, track, args)

    def complete(self, name, cat, t0, rid=None, track=None, **args):
        """Emit an ``X`` span that started at wall time ``t0`` (a value
        previously read from :meth:`now`) and ends now."""
        ev = {"ph": "X", "name": name, "cat": cat, "ts": t0,
              "step": self.step, "dur": self.now() - t0}
        if rid is not None:
            ev["rid"] = int(rid)
        if track is not None:
            ev["track"] = str(track)
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name, values, rid=None):
        """Sample a gauge set, e.g. ``{"used": 12, "pinned": 3}``."""
        self._ev("C", name, "counter", rid, None, {}, values=dict(values))

    # ------------------------------------------------------------ export --
    def header(self) -> dict:
        return {"schema": SCHEMA, "meta": dict(self.meta_args)}

    def dump_jsonl(self, path: str) -> None:
        """Native export: header line, then one event per line."""
        with open(path, "w") as f:
            f.write(json.dumps(self.header()) + "\n")
            for ev in self.events:
                f.write(json.dumps(ev) + "\n")

    def dump_chrome(self, path: str) -> None:
        chrome = to_chrome(self.events, self.meta_args)
        with open(path, "w") as f:
            json.dump(chrome, f)


def load_jsonl(path: str):
    """Read a native trace file back: ``(header, events)``. The
    round-trip is exact (events are JSON-plain when emitted), which
    ``tests/test_obs.py`` pins."""
    with open(path) as f:
        lines = [json.loads(ln) for ln in f if ln.strip()]
    if not lines or lines[0].get("schema") != SCHEMA:
        raise ValueError(f"{path}: not a {SCHEMA} trace file")
    return lines[0], lines[1:]


# ------------------------------------------------------- chrome export ----
#: pid used for engine-global (requestless) events in the Chrome view.
ENGINE_PID = 0


def _track_sort_key(track: str):
    # plan first, then transitions in tid order, conclusion last
    order = {"plan": 0, "serial": 0, "conclusion": 10**6}
    if track in order:
        return order[track]
    if track.startswith("t") and track[1:].isdigit():
        return int(track[1:])
    return 10**5


def to_chrome(events: List[dict], meta: Optional[dict] = None) -> dict:
    """Convert native events to Chrome trace-event JSON (Perfetto-
    loadable). Each request rid becomes a process; each distinct track
    within a request becomes a named thread, so the DAG frontier's
    parallel streams render as overlapping slices."""
    out: List[dict] = []
    # assign a stable tid per (pid, track)
    tids: Dict[tuple, int] = {}

    def tid_of(pid: int, track: str) -> int:
        key = (pid, track)
        if key not in tids:
            tids[key] = _track_sort_key(track)
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tids[key], "args": {"name": track}})
        return tids[key]

    pids_seen = set()

    def pid_of(ev: dict) -> int:
        pid = ev.get("rid", ENGINE_PID) if ev.get("rid") is not None \
            else ENGINE_PID
        if pid not in pids_seen:
            pids_seen.add(pid)
            name = "engine" if pid == ENGINE_PID else f"request {pid}"
            out.append({"ph": "M", "name": "process_name", "pid": pid,
                        "args": {"name": name}})
        return pid

    for ev in events:
        pid = pid_of(ev)
        track = ev.get("track", ev["cat"])
        base = {"name": ev["name"], "cat": ev["cat"], "pid": pid,
                "ts": ev["ts"] * 1e6,
                "args": dict(ev.get("args", {}), step=ev["step"])}
        ph = ev["ph"]
        if ph in ("B", "E"):
            out.append(dict(base, ph=ph, tid=tid_of(pid, track)))
        elif ph == "X":
            out.append(dict(base, ph="X", dur=ev["dur"] * 1e6,
                            tid=tid_of(pid, track)))
        elif ph == "I":
            out.append(dict(base, ph="i", s="t",
                            tid=tid_of(pid, track)))
        elif ph == "C":
            out.append({"ph": "C", "name": ev["name"], "pid": pid,
                        "tid": 0, "ts": ev["ts"] * 1e6,
                        "args": ev.get("values", {})})
    return {"traceEvents": out,
            "otherData": dict(meta or {}, schema=SCHEMA)}


# ----------------------------------------------------------- validation ---
def validate_spans(events: List[dict]) -> List[str]:
    """Structural check shared by tests: every ``B`` on a ``(rid,
    track, name)`` lane must be closed by a matching ``E``, LIFO per
    lane, none left open. Returns a list of problem strings (empty =
    clean). ``tools/check_trace.py`` re-implements this stdlib-only for
    CI use on trace *files*."""
    open_spans: Dict[tuple, List[str]] = {}
    problems: List[str] = []
    for i, ev in enumerate(events):
        if ev["ph"] not in ("B", "E"):
            continue
        lane = (ev.get("rid"), ev.get("track"))
        stack = open_spans.setdefault(lane, [])
        if ev["ph"] == "B":
            stack.append(ev["name"])
        else:
            if not stack:
                problems.append(
                    f"event {i}: E {ev['name']!r} on lane {lane} with no "
                    f"open span")
            elif stack[-1] != ev["name"]:
                problems.append(
                    f"event {i}: E {ev['name']!r} closes {stack[-1]!r} "
                    f"on lane {lane}")
                stack.pop()
            else:
                stack.pop()
    for lane, stack in open_spans.items():
        for name in stack:
            problems.append(f"span {name!r} on lane {lane} never closed")
    return problems
