"""Whitespace word-level tokenizer with MedVerse structural specials.

The structured tags (<Plan>, <Step>, ...) are single tokens so the
engine detects phase boundaries (e.g. pausing at </Plan> — paper Sec 4.3
Phase I) by token id, with zero text re-scanning per step.
"""

from __future__ import annotations

import json
import re
from typing import Dict, Iterable, List, Optional

SPECIALS = [
    "<pad>", "<unk>", "<bos>", "<eos>",
    "<Think>", "</Think>",
    "<Plan>", "</Plan>",
    "<Outline>", "</Outline>",
    "<Execution>", "</Execution>",
    "<Step>", "</Step>",
    "<Conclusion>", "</Conclusion>",
]

PAD, UNK, BOS, EOS = 0, 1, 2, 3

_SPECIAL_RE = re.compile(
    "(" + "|".join(re.escape(s) for s in SPECIALS[4:]) + ")"
)


class Tokenizer:
    def __init__(self, vocab: Dict[str, int]):
        self.vocab = vocab
        self.inv = {i: t for t, i in vocab.items()}

    # -- construction -------------------------------------------------------
    @staticmethod
    def train(corpus: Iterable[str], max_vocab: int = 8192) -> "Tokenizer":
        from collections import Counter

        counts: Counter = Counter()
        for text in corpus:
            for piece in _SPECIAL_RE.split(text):
                if piece in SPECIALS:
                    continue
                counts.update(piece.split())
        vocab = {s: i for i, s in enumerate(SPECIALS)}
        for word, _ in counts.most_common(max_vocab - len(vocab)):
            vocab[word] = len(vocab)
        return Tokenizer(vocab)

    @property
    def vocab_size(self) -> int:
        return len(self.vocab)

    def token_id(self, tok: str) -> int:
        return self.vocab.get(tok, UNK)

    # -- encode/decode ------------------------------------------------------
    def encode(self, text: str, bos: bool = False, eos: bool = False) -> List[int]:
        ids: List[int] = [BOS] if bos else []
        for piece in _SPECIAL_RE.split(text):
            if not piece:
                continue
            if piece in self.vocab and piece in SPECIALS:
                ids.append(self.vocab[piece])
            else:
                ids.extend(self.vocab.get(w, UNK) for w in piece.split())
        if eos:
            ids.append(EOS)
        return ids

    def decode(self, ids: Iterable[int]) -> str:
        toks = [self.inv.get(int(i), "<unk>") for i in ids]
        toks = [t for t in toks if t not in ("<pad>", "<bos>", "<eos>")]
        return " ".join(toks)

    # -- persistence ----------------------------------------------------
    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.vocab, f)

    @staticmethod
    def load(path: str) -> "Tokenizer":
        with open(path) as f:
            return Tokenizer(json.load(f))
