"""Dataset construction: curated examples -> packed, DAG-masked training
batches (tokens / targets / loss_mask / seg_id / layer_id / pos_id).

Next-token targets are *segment-local*: the prediction crossing a packed
segment boundary is masked (the engine force-feeds step headers, and a
branch's first token has no intra-segment predecessor). Question+options
tokens are masked; <Think>/<Plan>/steps/conclusion are supervised.

``causal=True`` re-encodes the same text linearly (seg 0 everywhere,
monotonic positions) — the Auto-Ser / Auto-Par training arms of the
paper's Table 8 ablation.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.topology import PAD_SEG, SequenceTopology, topology_from_dag
from .curator import CuratedExample
from .tokenizer import PAD, Tokenizer


@dataclasses.dataclass
class EncodedExample:
    qid: int
    tokens: np.ndarray      # (S,)
    targets: np.ndarray     # (S,)
    loss_mask: np.ndarray   # (S,) float32
    seg_id: np.ndarray
    layer_id: np.ndarray
    pos_id: np.ndarray
    seg_visible: np.ndarray
    answer_letter: str
    topology: str

    @property
    def length(self) -> int:
        return int(self.tokens.shape[0])


def encode_example(ex: CuratedExample, tok: Tokenizer,
                   causal: bool = False) -> EncodedExample:
    q_opts_len = len(tok.encode(
        ex.question + " Options : "
        + " ".join(f"{l} ) {o}" for l, o in zip("abcd", ex.options)),
        bos=True))
    prefix_ids = tok.encode(ex.prefix_text, bos=True)
    step_ids = {t: tok.encode(ex.step_texts[t]) for t in ex.dag.nodes}
    conc_ids = tok.encode(ex.conclusion_text, eos=True)

    topo, order = topology_from_dag(
        ex.dag, len(prefix_ids), {t: len(step_ids[t]) for t in ex.dag.nodes},
        len(conc_ids))
    tokens = np.concatenate(
        [np.asarray(prefix_ids, np.int32)]
        + [np.asarray(step_ids[t], np.int32) for t in order]
        + [np.asarray(conc_ids, np.int32)])
    assert tokens.shape[0] == topo.length

    seg = topo.seg_id.copy()
    lay = topo.layer_id.copy()
    pos = topo.pos_id.copy()
    vis = topo.seg_visible
    if causal:
        seg = np.zeros_like(seg)
        lay = np.zeros_like(lay)
        pos = np.arange(tokens.shape[0], dtype=np.int32)
        vis = np.ones((1, 1), dtype=bool)

    s = tokens.shape[0]
    targets = np.full((s,), PAD, np.int32)
    targets[:-1] = tokens[1:]
    same_seg = np.zeros((s,), bool)
    same_seg[:-1] = seg[:-1] == seg[1:] if not causal else True
    if causal:
        same_seg[:-1] = True
        same_seg[-1] = False
    loss_mask = same_seg.astype(np.float32)
    loss_mask[:q_opts_len] = 0.0  # don't supervise the question/options
    return EncodedExample(
        qid=ex.qid, tokens=tokens, targets=targets, loss_mask=loss_mask,
        seg_id=seg, layer_id=lay, pos_id=pos, seg_visible=vis,
        answer_letter=ex.answer_letter, topology=ex.topology,
    )


def pad_example(e: EncodedExample, seq_len: int) -> EncodedExample:
    s = e.length
    if s > seq_len:
        raise ValueError(f"example length {s} > seq_len {seq_len}")
    pad = seq_len - s

    def p(a, fill):
        return np.concatenate([a, np.full((pad,), fill, a.dtype)])

    return EncodedExample(
        qid=e.qid,
        tokens=p(e.tokens, PAD),
        targets=p(e.targets, PAD),
        loss_mask=p(e.loss_mask, 0.0),
        seg_id=p(e.seg_id, PAD_SEG),
        layer_id=p(e.layer_id, -1),
        pos_id=p(e.pos_id, 0),
        seg_visible=e.seg_visible,
        answer_letter=e.answer_letter,
        topology=e.topology,
    )


def make_batches(examples: Sequence[EncodedExample], batch_size: int,
                 seq_len: int, seed: int = 0,
                 drop_too_long: bool = True) -> List[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    usable = [e for e in examples if e.length <= seq_len or not drop_too_long]
    idx = rng.permutation(len(usable))
    batches = []
    for i in range(0, len(usable) - batch_size + 1, batch_size):
        group = [pad_example(usable[j], seq_len) for j in idx[i:i + batch_size]]
        n_seg = max(g.seg_visible.shape[0] for g in group)
        vis = np.zeros((batch_size, n_seg, n_seg), bool)
        for bi, g in enumerate(group):
            k = g.seg_visible.shape[0]
            vis[bi, :k, :k] = g.seg_visible
        batches.append({
            "tokens": np.stack([g.tokens for g in group]),
            "targets": np.stack([g.targets for g in group]),
            "loss_mask": np.stack([g.loss_mask for g in group]),
            "seg_id": np.stack([g.seg_id for g in group]),
            "layer_id": np.stack([g.layer_id for g in group]),
            "pos_id": np.stack([g.pos_id for g in group]),
            "seg_visible": vis,
        })
    return batches


@dataclasses.dataclass
class Corpus:
    """End-to-end synthetic MedVerse corpus (the MedVerse-14K analogue)."""

    tokenizer: Tokenizer
    train: List[CuratedExample]
    eval: List[CuratedExample]

    @staticmethod
    def build(n_items: int = 600, eval_frac: float = 0.15, seed: int = 0,
              n_clusters: int = 60, max_vocab: int = 8192) -> "Corpus":
        from .knowledge_graph import build_kg, generate_qa
        from .curator import Curator

        kg = build_kg(n_clusters, seed=seed)
        items = generate_qa(kg, n_items, seed=seed + 1)
        curator = Curator(kg, seed=seed + 2)
        examples = curator.curate_all(items)
        texts = [ex.prefix_text + " "
                 + " ".join(ex.step_texts[t] for t in sorted(ex.step_texts))
                 + " " + ex.conclusion_text for ex in examples]
        tok = Tokenizer.train(texts, max_vocab=max_vocab)
        n_eval = max(1, int(len(examples) * eval_frac))
        return Corpus(tokenizer=tok, train=examples[:-n_eval],
                      eval=examples[-n_eval:])
