"""Synthetic medical knowledge graph + QA item generation.

Stands in for the UMLS-scale KG the paper's Curator retrieves from
(DESIGN.md §6). A seed of genuine clinical relations (including the
paper's own thyrotoxicosis example, Fig. 3) is expanded procedurally
with synthetic disease clusters so the Curator has enough structure to
mine thousands of multi-path reasoning topologies.

Entities are typed (disease / symptom / finding / test / treatment /
mechanism); edges are typed, directed clinical relations.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Set, Tuple

RELATIONS = (
    "presents_with",   # disease -> symptom
    "causes",          # disease/mechanism -> finding
    "indicated_by",    # disease -> test finding
    "treated_by",      # disease -> treatment
    "acts_via",        # treatment -> mechanism
    "reduces",         # treatment/mechanism -> finding
    "increases",       # mechanism -> finding
    "suggests",        # symptom/finding -> disease
)

VERBALIZE = {
    "presents_with": "{a} classically presents with {b}.",
    "causes": "{a} causes {b} through its underlying pathophysiology.",
    "indicated_by": "{a} is indicated by {b} on diagnostic workup.",
    "treated_by": "{a} is managed with {b} as a standard intervention.",
    "acts_via": "{a} acts via {b} at the tissue level.",
    "reduces": "{a} reduces {b} by suppressing the driving process.",
    "increases": "{a} increases {b} in the acute setting.",
    "suggests": "{a} suggests {b} in the differential diagnosis.",
}


@dataclasses.dataclass(frozen=True)
class Edge:
    src: str
    rel: str
    dst: str


# A small, genuine clinical seed (incl. the paper's Fig. 3 example).
SEED_EDGES: List[Tuple[str, str, str]] = [
    ("Thyrotoxicosis", "presents_with", "Tachycardia"),
    ("Thyrotoxicosis", "presents_with", "Weight-loss"),
    ("Thyrotoxicosis", "presents_with", "Heat-intolerance"),
    ("Thyrotoxicosis", "treated_by", "Potassium-iodide"),
    ("Thyrotoxicosis", "treated_by", "Therapeutic-iodine"),
    ("Thyrotoxicosis", "treated_by", "Methimazole"),
    ("Potassium-iodide", "acts_via", "Wolff-Chaikoff-effect"),
    ("Therapeutic-iodine", "acts_via", "Wolff-Chaikoff-effect"),
    ("Potassium-iodide", "reduces", "Thyroid-vascularity"),
    ("Therapeutic-iodine", "reduces", "Thyroid-vascularity"),
    ("Wolff-Chaikoff-effect", "reduces", "Thyroid-hormone-release"),
    ("Myocardial-infarction", "presents_with", "Chest-pain"),
    ("Myocardial-infarction", "presents_with", "Diaphoresis"),
    ("Myocardial-infarction", "indicated_by", "ST-elevation"),
    ("Myocardial-infarction", "indicated_by", "Troponin-rise"),
    ("Myocardial-infarction", "treated_by", "Aspirin"),
    ("Myocardial-infarction", "treated_by", "PCI"),
    ("Aspirin", "acts_via", "COX-inhibition"),
    ("COX-inhibition", "reduces", "Platelet-aggregation"),
    ("PCI", "reduces", "Coronary-occlusion"),
    ("Pneumonia", "presents_with", "Productive-cough"),
    ("Pneumonia", "presents_with", "Fever"),
    ("Pneumonia", "indicated_by", "Lobar-consolidation"),
    ("Pneumonia", "treated_by", "Amoxicillin"),
    ("Amoxicillin", "acts_via", "Cell-wall-synthesis-inhibition"),
    ("Cell-wall-synthesis-inhibition", "reduces", "Bacterial-load"),
    ("Diabetic-ketoacidosis", "presents_with", "Polyuria"),
    ("Diabetic-ketoacidosis", "presents_with", "Kussmaul-breathing"),
    ("Diabetic-ketoacidosis", "indicated_by", "Anion-gap-acidosis"),
    ("Diabetic-ketoacidosis", "treated_by", "Insulin-infusion"),
    ("Insulin-infusion", "reduces", "Ketogenesis"),
    ("Insulin-infusion", "reduces", "Serum-glucose"),
    ("Iron-deficiency-anemia", "presents_with", "Fatigue"),
    ("Iron-deficiency-anemia", "presents_with", "Pallor"),
    ("Iron-deficiency-anemia", "indicated_by", "Low-ferritin"),
    ("Iron-deficiency-anemia", "treated_by", "Ferrous-sulfate"),
    ("Ferrous-sulfate", "increases", "Hemoglobin-synthesis"),
]


class KnowledgeGraph:
    def __init__(self, edges: Sequence[Edge]):
        self.edges = list(edges)
        self.out: Dict[str, List[Edge]] = {}
        self.entities: Set[str] = set()
        self.edge_set: Set[Tuple[str, str]] = set()
        for e in self.edges:
            self.out.setdefault(e.src, []).append(e)
            self.entities.add(e.src)
            self.entities.add(e.dst)
            self.edge_set.add((e.src, e.dst))

    def has_edge(self, a: str, b: str) -> bool:
        return (a, b) in self.edge_set

    def relation(self, a: str, b: str) -> Optional[str]:
        for e in self.out.get(a, []):
            if e.dst == b:
                return e.rel
        return None

    def successors(self, a: str) -> List[str]:
        return [e.dst for e in self.out.get(a, [])]

    def paths(self, src: str, dst: str, max_hops: int = 4,
              max_paths: int = 24) -> List[List[str]]:
        """DFS path retrieval (Curator Phase 1: knowledge retrieval)."""
        out: List[List[str]] = []
        stack: List[List[str]] = [[src]]
        while stack and len(out) < max_paths:
            path = stack.pop()
            node = path[-1]
            if node == dst and len(path) > 1:
                out.append(path)
                continue
            if len(path) > max_hops:
                continue
            for nxt in self.successors(node):
                if nxt not in path:  # simple paths only (acyclic)
                    stack.append(path + [nxt])
        return out


def build_kg(n_synthetic_clusters: int = 60, seed: int = 0) -> KnowledgeGraph:
    """Seed KG + procedural clusters. Each cluster mirrors a clinical
    motif: disease -> {symptoms, findings} ; disease -> treatments ->
    shared mechanism -> outcome finding (a diamond — the structure that
    exercises Fork/Join)."""
    rng = random.Random(seed)
    edges = [Edge(*t) for t in SEED_EDGES]
    for k in range(n_synthetic_clusters):
        d = f"Syndrome-{k:02d}"
        n_sym = rng.randint(2, 4)
        for i in range(n_sym):
            edges.append(Edge(d, "presents_with", f"Sign-{k:02d}-{i}"))
        edges.append(Edge(d, "indicated_by", f"Marker-{k:02d}"))
        n_treat = rng.randint(2, 3)
        mech = f"Pathway-{k:02d}"
        outcome = f"Outcome-{k:02d}"
        for i in range(n_treat):
            t = f"Agent-{k:02d}-{i}"
            edges.append(Edge(d, "treated_by", t))
            edges.append(Edge(t, "acts_via", mech))
            edges.append(Edge(t, "reduces", outcome))
        edges.append(Edge(mech, "reduces", f"Driver-{k:02d}"))
        # cross-links to earlier clusters (intersecting topologies)
        if k > 0 and rng.random() < 0.5:
            other = f"Outcome-{rng.randrange(k):02d}"
            edges.append(Edge(mech, "increases", other))
        if rng.random() < 0.4:
            edges.append(Edge(f"Sign-{k:02d}-0", "suggests", d))
    return KnowledgeGraph(edges)


@dataclasses.dataclass
class QAItem:
    qid: int
    question: str
    options: List[str]         # option texts
    answer_idx: int            # index into options
    question_entities: List[str]
    answer_entity: str

    @property
    def answer_letter(self) -> str:
        return "abcd"[self.answer_idx]

    @property
    def answer_text(self) -> str:
        return self.options[self.answer_idx]


_Q_TEMPLATES = [
    ("A patient has {disease} . Which intervention reduces {outcome} ?",
     "treatment_for_outcome"),
    ("A patient presents with {signs} . The diagnosis is {disease} . "
     "Which agent is appropriate ?", "treatment"),
]


def generate_qa(kg: KnowledgeGraph, n_items: int = 512,
                seed: int = 1) -> List[QAItem]:
    rng = random.Random(seed)
    diseases = sorted({e.src for e in kg.edges if e.rel == "treated_by"})
    all_treatments = sorted({e.dst for e in kg.edges if e.rel == "treated_by"})
    items: List[QAItem] = []
    qid = 0
    while len(items) < n_items:
        d = rng.choice(diseases)
        treatments = [e.dst for e in kg.out[d] if e.rel == "treated_by"]
        if not treatments:
            continue
        ans = rng.choice(treatments)
        # outcome the answer reaches (for the question text)
        outs = [e.dst for e in kg.out.get(ans, []) if e.rel == "reduces"]
        signs = [e.dst for e in kg.out[d] if e.rel == "presents_with"]
        distractors = [t for t in all_treatments
                       if t not in treatments]
        rng.shuffle(distractors)
        options = [ans] + distractors[:3]
        rng.shuffle(options)
        if outs:
            q = (f"A patient has {d} . Which intervention reduces "
                 f"{outs[0]} ?")
        elif signs:
            q = (f"A patient presents with {' and '.join(signs[:2])} . "
                 f"The diagnosis is {d} . Which agent is appropriate ?")
        else:
            continue
        items.append(QAItem(
            qid=qid, question=q, options=options,
            answer_idx=options.index(ans),
            question_entities=[d] + signs[:2],
            answer_entity=ans,
        ))
        qid += 1
    return items
