from .curator import CuratedExample, Curator, CuratorStats
from .dataset import Corpus, EncodedExample, encode_example, make_batches, pad_example
from .knowledge_graph import KnowledgeGraph, QAItem, build_kg, generate_qa
from .tokenizer import EOS, PAD, SPECIALS, Tokenizer

__all__ = [
    "CuratedExample", "Curator", "CuratorStats",
    "Corpus", "EncodedExample", "encode_example", "make_batches", "pad_example",
    "KnowledgeGraph", "QAItem", "build_kg", "generate_qa",
    "EOS", "PAD", "SPECIALS", "Tokenizer",
]
