"""MedVerse Curator: the 4-phase pipeline that turns (question, answer)
pairs + the knowledge graph into Petri-Net-structured training examples
(paper Sec. 4.1 + Appendix B/C).

Phase 1 — Knowledge-grounded retrieval: entity mapping + KG path search
          from question entities to the answer entity.
Phase 2 — Topological planning: filtering rules (relevance, consistency,
          dedup, cap 10, text integrity — Appendix C), path editing
          (bridge insertion), DAG consolidation + validity check
          (cycles -> reject/re-route).
Phase 3 — Structural synthesis: <Think>/<Plan> rendering, per-transition
          step synthesis via the rule-based teacher (relation
          verbalization), cross-branch refinement (dedup of repeated
          facts), conclusion synthesis.
Phase 4 — Dual-layer verification: (a) syntax — the rendered text must
          reparse into the same DAG with matching step indices;
          (b) logic — every reasoning edge must exist in the KG and the
          conclusion must name the gold answer. Failures regenerate.

The "teacher LLM" of the paper is a deterministic rule-based renderer
here (DESIGN.md §6): structure faithful, prose synthetic.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.dag import CycleError, ReasoningDAG, merge_paths_to_dag
from ..core.plan import (
    OutlineStep,
    ReasoningPlan,
    parse_answer,
    parse_plan,
    parse_steps,
    render_conclusion,
    render_step,
    render_think,
)
from .knowledge_graph import VERBALIZE, KnowledgeGraph, QAItem


@dataclasses.dataclass
class CuratedExample:
    qid: int
    question: str
    options: List[str]
    answer_letter: str
    answer_text: str
    prefix_text: str                  # question + options + think + plan
    step_texts: Dict[int, str]        # 0-based tid -> "<Step> ... </Step>"
    conclusion_text: str
    plan: ReasoningPlan
    dag: ReasoningDAG
    topology: str
    question_entities: List[str] = dataclasses.field(default_factory=list)

    def linear_text(self) -> str:
        """Serialized in packed (frontier-layer) order — what a purely
        autoregressive baseline trains on."""
        order = [t for layer in self.dag.topological_layers() for t in layer]
        return " ".join([self.prefix_text]
                        + [self.step_texts[t] for t in order]
                        + [self.conclusion_text])


@dataclasses.dataclass
class CuratorStats:
    n_items: int = 0
    n_no_paths: int = 0
    n_cycle_rejected: int = 0
    n_syntax_fail: int = 0
    n_logic_fail: int = 0
    n_regenerated: int = 0
    n_ok: int = 0


class Curator:
    def __init__(self, kg: KnowledgeGraph, seed: int = 0,
                 max_paths: int = 10, max_hops: int = 4):
        self.kg = kg
        self.rng = random.Random(seed)
        self.max_paths = max_paths
        self.max_hops = max_hops
        self.stats = CuratorStats()

    # ---------------------------------------------------------- phase 1 ---
    def retrieve_paths(self, item: QAItem) -> List[List[str]]:
        paths: List[List[str]] = []
        for src in item.question_entities:
            paths.extend(self.kg.paths(src, item.answer_entity,
                                       self.max_hops))
        # Some questions reason disease -> treatment -> outcome; also
        # admit paths THROUGH the answer to outcomes mentioned in text.
        for e in self.kg.out.get(item.answer_entity, []):
            for src in item.question_entities:
                for p in self.kg.paths(src, e.dst, self.max_hops):
                    if item.answer_entity in p:
                        paths.append(p)
        return paths

    # ---------------------------------------------------------- phase 2 ---
    def filter_paths(self, paths: List[List[str]],
                     item: QAItem) -> List[List[str]]:
        """Appendix C filtering rules: relevance (reaches answer entity or
        its direct effect), dedup (first occurrence), cap at max_paths,
        original order, no text edits."""
        seen = set()
        out: List[List[str]] = []
        for p in paths:
            key = tuple(p)
            if key in seen:
                continue
            seen.add(key)
            if item.answer_entity not in p:
                continue                    # relevance
            if len(p) < 2:
                continue
            out.append(p)
            if len(out) == self.max_paths:
                break
        return out

    def consolidate(self, paths: List[List[str]]
                    ) -> Tuple[ReasoningDAG, Dict[int, Tuple[str, Tuple[str, ...]]]]:
        """Merge paths into a transition DAG; DAG validity check rejects
        cyclic merges by dropping the newest offending path (re-route)."""
        work = list(paths)
        while work:
            try:
                return merge_paths_to_dag(work)
            except CycleError:
                self.stats.n_cycle_rejected += 1
                work = work[:-1]
        raise ValueError("no valid paths")

    # ---------------------------------------------------------- phase 3 ---
    def _step_body(self, srcs: Sequence[str], tgt: str) -> str:
        sents = []
        for s in srcs:
            rel = self.kg.relation(s, tgt)
            if rel is None:
                rel = "suggests"
            sents.append(VERBALIZE[rel].format(
                a=s.replace("-", " "), b=tgt.replace("-", " ")))
        return " ".join(sents)

    def synthesize(self, item: QAItem, dag: ReasoningDAG,
                   meta: Dict[int, Tuple[str, Tuple[str, ...]]],
                   paths: List[List[str]]) -> CuratedExample:
        labels = {}
        outlines = []
        for t in sorted(dag.nodes):
            tgt, srcs = meta[t]
            label = f"{' , '.join(s for s in srcs)} -> {tgt}"
            labels[t] = label
            outlines.append(OutlineStep(
                index=t + 1, label=label,
                dependencies=tuple(d + 1 for d in dag.predecessors(t)),
            ))
        plan = ReasoningPlan(steps=tuple(outlines))
        think = render_think([" -> ".join(p) for p in paths])
        opts = " ".join(f"{l} ) {o}" for l, o in zip("abcd", item.options))
        prefix = f"{item.question} Options : {opts} {think} {plan.serialize()}"

        # step synthesis + refinement (dedup repeated facts across branches)
        emitted = set()
        step_texts: Dict[int, str] = {}
        for t in sorted(dag.nodes):
            tgt, srcs = meta[t]
            body = self._step_body(srcs, tgt)
            sents = [s for s in body.split(". ") if s]
            fresh = [s for s in sents if s not in emitted]
            emitted.update(fresh)
            body = ". ".join(fresh) if fresh else sents[0]
            if not body.endswith("."):
                body += "."
            step_texts[t] = render_step(t + 1, labels[t], body)

        concl_steps = ", ".join(str(t + 1) for t in dag.sinks())
        explanation = (
            f"As established in Transient Steps {concl_steps} , the "
            f"reasoning converges on {item.answer_entity.replace('-', ' ')} ."
        )
        conclusion = render_conclusion(
            explanation, f"{item.answer_letter} ) {item.answer_text}")
        return CuratedExample(
            qid=item.qid, question=item.question, options=item.options,
            answer_letter=item.answer_letter, answer_text=item.answer_text,
            prefix_text=prefix, step_texts=step_texts,
            conclusion_text=conclusion, plan=plan, dag=dag,
            topology=dag.classify_topology(),
            question_entities=list(item.question_entities),
        )

    # ---------------------------------------------------------- phase 4 ---
    def verify(self, ex: CuratedExample, item: QAItem) -> Tuple[bool, str]:
        # (a) syntax: reparse and compare structure
        full = (ex.prefix_text + " "
                + " ".join(ex.step_texts[t] for t in sorted(ex.step_texts))
                + " " + ex.conclusion_text)
        try:
            plan2 = parse_plan(full)
            dag2 = plan2.to_dag()
        except Exception as e:
            return False, f"syntax: {e}"
        if dag2.deps != ex.dag.deps:
            return False, "syntax: reparsed DAG mismatch"
        steps2 = parse_steps(full)
        if set(steps2) != {t + 1 for t in ex.dag.nodes}:
            return False, "syntax: step indices do not match plan"
        # (b) logic: every edge grounded in the KG; answer correct
        for step in ex.plan.steps:
            if "->" not in step.label:
                return False, "logic: malformed step label"
            lhs, tgt = step.label.rsplit("->", 1)
            tgt = tgt.strip()
            for src in (s.strip() for s in lhs.split(",")):
                if src and not self.kg.has_edge(src, tgt):
                    return False, f"logic: edge {src}->{tgt} not in KG"
        ans = parse_answer(full)
        if ans is None or item.answer_text not in ans:
            return False, "logic: conclusion does not state the gold answer"
        return True, "ok"

    # ------------------------------------------------------------ drive ---
    def curate(self, item: QAItem, max_retries: int = 2
               ) -> Optional[CuratedExample]:
        self.stats.n_items += 1
        paths = self.filter_paths(self.retrieve_paths(item), item)
        if not paths:
            self.stats.n_no_paths += 1
            return None
        for attempt in range(max_retries + 1):
            try:
                dag, meta = self.consolidate(paths)
            except ValueError:
                self.stats.n_no_paths += 1
                return None
            ex = self.synthesize(item, dag, meta, paths)
            ok, why = self.verify(ex, item)
            if ok:
                self.stats.n_ok += 1
                return ex
            self.stats.n_regenerated += 1
            if why.startswith("syntax"):
                self.stats.n_syntax_fail += 1
            else:
                self.stats.n_logic_fail += 1
            # regenerate with fewer paths (re-route)
            paths = paths[:-1]
            if not paths:
                return None
        return None

    def curate_all(self, items: Sequence[QAItem]) -> List[CuratedExample]:
        out = []
        for it in items:
            ex = self.curate(it)
            if ex is not None:
                out.append(ex)
        return out
