"""Oracle: associative-scan RG-LRU (same math as models.rglru)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a: jnp.ndarray, b: jnp.ndarray,
                   h0: jnp.ndarray) -> jnp.ndarray:
    """h_t = a_t h_{t-1} + b_t with initial state h0. (B,S,W) -> (B,S,W)."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
    a = a.at[:, 0].set(0.0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h
