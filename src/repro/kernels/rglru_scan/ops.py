"""Jitted wrapper for the RG-LRU scan kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import rglru_scan_kernel


def _pick(n: int, target: int) -> int:
    if n % target == 0:
        return target
    for c in (64, 32, 16, 8, 4, 2, 1):
        if n % c == 0:
            return c
    return 1


@partial(jax.jit, static_argnames=("interpret",))
def rglru_scan(a: jnp.ndarray, b: jnp.ndarray,
               h0: jnp.ndarray = None, *, interpret: bool = True
               ) -> jnp.ndarray:
    bsz, s, w = a.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, w), jnp.float32)
    return rglru_scan_kernel(
        a, b, h0, block_w=_pick(w, 128), chunk=_pick(s, 128),
        interpret=interpret)
