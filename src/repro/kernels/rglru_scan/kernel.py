"""Pallas TPU kernel: RG-LRU linear recurrence (RecurrentGemma),

    h_t = a_t * h_{t-1} + b_t        (elementwise over width W)

blocked as (batch, width-block, seq-chunk) with the carry held in VMEM
scratch across sequence chunks (innermost, "arbitrary" grid axis). The
inner chunk loop is a VPU-elementwise fori_loop — no MXU involvement,
so the tile is sized for VMEM residency of (chunk, width-block).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, carry_ref, *, chunk: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        carry_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)   # (chunk, BW)
    b = b_ref[0].astype(jnp.float32)

    def step(t, carry):
        h = a[t] * carry + b[t]
        o_ref[0, t] = h.astype(o_ref.dtype)
        return h

    carry_ref[...] = jax.lax.fori_loop(0, chunk, step, carry_ref[...])


def rglru_scan_kernel(
    a: jnp.ndarray,    # (B, S, W) decay in (0,1)
    b: jnp.ndarray,    # (B, S, W) gated input
    h0: jnp.ndarray,   # (B, W) initial state
    *, block_w: int = 128, chunk: int = 128, interpret: bool = False,
) -> jnp.ndarray:
    bsz, s, w = a.shape
    block_w = min(block_w, w)
    chunk = min(chunk, s)
    assert w % block_w == 0 and s % chunk == 0, (w, block_w, s, chunk)
    grid = (bsz, w // block_w, s // chunk)
    kernel = functools.partial(_rglru_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_w),
                         lambda b_, wi, si: (b_, si, wi)),
            pl.BlockSpec((1, chunk, block_w),
                         lambda b_, wi, si: (b_, si, wi)),
            pl.BlockSpec((1, block_w), lambda b_, wi, si: (b_, wi)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_w),
                               lambda b_, wi, si: (b_, si, wi)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
