"""Pallas TPU kernel: flash attention with the MedVerse DAG mask
computed on the fly from O(S) topology metadata (paper Eq. 3).

Design (TPU-native, see DESIGN.md §3):
  * grid (batch, q_head, q_block, kv_block), kv innermost ("arbitrary"
    semantics) with running-softmax scratch in VMEM — the canonical TPU
    flash schedule; q/k/v tiles are MXU-aligned (block sizes multiples
    of 128 on real hardware; smaller in tests via interpret=True).
  * the (S,S) mask is never materialized: each (BQ, BK) tile derives
    Eq. 3 from seg_id/layer_id tiles resident in VMEM —
        blocked  iff  (kv after q in packed order)
                  or  (same frontier layer AND different segment)
                  or  padding,
    plus an optional sliding window on *adaptive* positions (gemma3 /
    recurrentgemma local layers compose window AND dag).
  * statically causal-skippable tiles (kv block entirely after the q
    block) are skipped with pl.when — no FLOPs, no VMEM traffic.
  * GQA: kv head index = q head // group (index_map arithmetic, no
    repeat-interleave materialization).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
PAD_SEG = -1


def _flash_dag_kernel(
    # metadata tiles
    seg_q_ref, lay_q_ref, pos_q_ref,
    seg_k_ref, lay_k_ref, pos_k_ref,
    # tensor tiles
    q_ref, k_ref, v_ref,
    # outputs
    o_ref,
    # scratch
    m_ref, l_ref, acc_ref,
    *, scale: float, block_q: int, block_k: int, n_kblocks: int,
    window: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # static causal block skip: kv tile strictly after q tile
    @pl.when(ki * block_k <= qi * block_q + block_q - 1)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (BQ, HD)
        k = k_ref[0, 0].astype(jnp.float32)          # (BK, HD)
        v = v_ref[0, 0].astype(jnp.float32)          # (BK, HD)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (BQ, BK)

        seg_q = seg_q_ref[0]                          # (BQ,)
        lay_q = lay_q_ref[0]
        pos_q = pos_q_ref[0]
        seg_k = seg_k_ref[0]
        lay_k = lay_k_ref[0]
        pos_k = pos_k_ref[0]
        gq = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        gk = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        causal = gk <= gq                              # packed order
        same_layer = lay_q[:, None] == lay_k[None, :]
        same_seg = seg_q[:, None] == seg_k[None, :]
        valid = (seg_q[:, None] != PAD_SEG) & (seg_k[None, :] != PAD_SEG)
        allowed = causal & ~(same_layer & ~same_seg) & valid
        if window > 0:
            diff = pos_q[:, None] - pos_k[None, :]
            allowed = allowed & (diff >= 0) & (diff < window)
        s = jnp.where(allowed, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        # explicit zero for masked entries (a fully-masked tile with the
        # running max still at -inf must not contribute exp(0) weights)
        p = jnp.where(allowed, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = (
            acc_ref[...] * corr[:, None]
            + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        )
        m_ref[...] = m_new

    @pl.when(ki == n_kblocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def dag_flash_attention_kernel(
    q: jnp.ndarray,       # (B, NH, S, HD)
    k: jnp.ndarray,       # (B, NKV, S, HD)
    v: jnp.ndarray,
    seg_id: jnp.ndarray,  # (B, S) int32
    layer_id: jnp.ndarray,
    pos_id: jnp.ndarray,
    *,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, nh, s, hd = q.shape
    nkv = k.shape[1]
    g = nh // nkv
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    n_q, n_k = s // block_q, s // block_k
    scale = 1.0 / math.sqrt(hd)

    grid = (b, nh, n_q, n_k)
    kernel = functools.partial(
        _flash_dag_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_kblocks=n_k, window=window,
    )
    meta_q_spec = pl.BlockSpec((1, block_q), lambda b_, h, qi, ki: (b_, qi))
    meta_k_spec = pl.BlockSpec((1, block_k), lambda b_, h, qi, ki: (b_, ki))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            meta_q_spec, meta_q_spec, meta_q_spec,
            meta_k_spec, meta_k_spec, meta_k_spec,
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b_, h, qi, ki: (b_, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b_, h, qi, ki: (b_, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nh, s, hd), q.dtype),
        scratch_shapes=[
            # running max / sum / accumulator live in VMEM across kv tiles
            # (the grid revisits the same output block along the kv axis;
            # kv is the innermost, "arbitrary"-semantics dimension)
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(seg_id, layer_id, pos_id, seg_id, layer_id, pos_id, q, k, v)
