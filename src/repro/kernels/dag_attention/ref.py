"""Pure-jnp oracle for the dag_attention kernel (shares the mask
definition with repro.core.masks — Eq. 3)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30
PAD_SEG = -1


def dag_attention_ref(q, k, v, seg_id, layer_id, pos_id, *, window: int = 0):
    """q: (B, NH, S, HD); k, v: (B, NKV, S, HD); metadata (B, S).
    Returns (B, NH, S, HD) float32 attention output."""
    b, nh, s, hd = q.shape
    nkv = k.shape[1]
    g = nh // nkv
    idx = jnp.arange(s)
    causal = idx[None, :] <= idx[:, None]
    same_layer = layer_id[:, :, None] == layer_id[:, None, :]
    same_seg = seg_id[:, :, None] == seg_id[:, None, :]
    valid = (seg_id[:, :, None] != PAD_SEG) & (seg_id[:, None, :] != PAD_SEG)
    allowed = causal[None] & ~(same_layer & ~same_seg) & valid
    if window > 0:
        diff = pos_id[:, :, None] - pos_id[:, None, :]
        allowed = allowed & (diff >= 0) & (diff < window)
    qg = q.reshape(b, nkv, g, s, hd).astype(jnp.float32)
    sc = jnp.einsum("bkgqh,bksh->bkgqs", qg, k.astype(jnp.float32))
    sc = sc / math.sqrt(hd)
    sc = jnp.where(allowed[:, None, None], sc, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bkgqh", w, v.astype(jnp.float32))
    return out.reshape(b, nh, s, hd)
