"""Jitted public wrapper for dag_attention: layout handling, block-size
selection, padding, and the interpret switch (CPU validation vs TPU)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import dag_flash_attention_kernel
from .ref import PAD_SEG


def _pick_block(s: int, target: int = 128) -> int:
    if s % target == 0:
        return target
    for b in (64, 32, 16, 8):
        if s % b == 0:
            return b
    return s


@partial(jax.jit, static_argnames=("window", "interpret", "block_q",
                                   "block_k"))
def dag_attention(
    q: jnp.ndarray,        # (B, S, NH, HD) — model layout
    k: jnp.ndarray,        # (B, S, NKV, HD)
    v: jnp.ndarray,
    seg_id: jnp.ndarray,   # (B, S)
    layer_id: jnp.ndarray,
    pos_id: jnp.ndarray,
    *,
    window: int = 0,
    block_q: int = 0,
    block_k: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    """MedVerse DAG flash attention. Returns (B, S, NH, HD)."""
    b, s, nh, hd = q.shape
    bq = block_q or _pick_block(s)
    bk = block_k or _pick_block(s)
    pad = (-s) % max(bq, bk)
    if pad:
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, padw)
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
        seg_id = jnp.pad(seg_id, ((0, 0), (0, pad)),
                         constant_values=PAD_SEG)
        layer_id = jnp.pad(layer_id, ((0, 0), (0, pad)), constant_values=-1)
        pos_id = jnp.pad(pos_id, ((0, 0), (0, pad)))
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = dag_flash_attention_kernel(
        qt, kt, vt, seg_id.astype(jnp.int32), layer_id.astype(jnp.int32),
        pos_id.astype(jnp.int32),
        window=window, block_q=bq, block_k=bk, interpret=interpret,
    )
    out = out.transpose(0, 2, 1, 3)
    if pad:
        out = out[:, :s]
    return out


@partial(jax.jit, static_argnames=("window", "interpret"))
def causal_prefill_attention(
    q: jnp.ndarray,        # (B, S, NH, HD) — model layout
    k: jnp.ndarray,        # (B, S, NKV, HD)
    v: jnp.ndarray,
    pos: jnp.ndarray,      # (B, S) adaptive positions (engine prefill)
    *,
    window: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    """Linear (causal) prefill through the DAG flash kernel.

    The engine's Phase-I prefill is a single linear segment, i.e. the
    degenerate DAG topology: one segment, one frontier layer. Eq. 3 then
    reduces to plain causal masking (plus the optional sliding window on
    the *adaptive* positions), so the same chunked flash kernel serves
    both the engine prefill hot path and full DAG-masked training.
    Returns (B, S, NH, HD).
    """
    b, s = q.shape[:2]
    seg = jnp.zeros((b, s), jnp.int32)
    lay = jnp.zeros((b, s), jnp.int32)
    return dag_attention(q, k, v, seg, lay, pos.astype(jnp.int32),
                         window=window, interpret=interpret)
