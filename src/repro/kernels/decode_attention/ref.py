"""Pure-jnp oracle for paged decode attention."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_decode_attention_ref(q, k_pool, v_pool, pool_pos, page_table,
                               page_valid, q_pos, *, window: int = 0,
                               k_scale=None, v_scale=None):
    """Dense gather + masked softmax. Shapes as in kernel.py. With an
    int8 pool, ``k_scale``/``v_scale`` are (n_pages, NKV) absmax scales
    and the gather dequantizes before the softmax."""
    b, nkv, g, hd = q.shape
    n_pages, page_size = k_pool.shape[:2]
    p_max = page_table.shape[1]
    # gather chain tokens: (B, P_max, page, NKV, HD)
    k = k_pool[page_table]
    v = v_pool[page_table]
    if k_scale is not None:
        k = k.astype(jnp.float32) * k_scale[page_table][:, :, None, :, None]
        v = v.astype(jnp.float32) * v_scale[page_table][:, :, None, :, None]
    pos = pool_pos[page_table]                       # (B, P_max, page)
    i = jnp.arange(page_size)
    visible = i[None, None, :] < page_valid[:, :, None]
    visible = visible & (pos <= q_pos[:, None, None])
    if window > 0:
        diff = q_pos[:, None, None] - pos
        visible = visible & (diff >= 0) & (diff < window)
    k = k.reshape(b, p_max * page_size, nkv, hd)
    v = v.reshape(b, p_max * page_size, nkv, hd)
    vis = visible.reshape(b, p_max * page_size)
    sc = jnp.einsum("bkgh,bskh->bkgs", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / math.sqrt(hd)
    sc = jnp.where(vis[:, None, None, :], sc, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1)
    # fully-masked rows produce 0 (kernel convention), not a uniform avg
    any_vis = vis.any(axis=-1)[:, None, None, None]
    w = jnp.where(any_vis, w, 0.0)
    return jnp.einsum("bkgs,bskh->bkgh", w, v.astype(jnp.float32))
