"""Pallas TPU kernel: paged GQA decode attention — the TPU-native
analogue of radix attention (paper Sec. 4.3; DESIGN.md §3).

One query token per stream attends over its *page chain*: the page
table is a scalar-prefetch argument (SMEM), and the BlockSpec index_map
reads it to stream exactly the chain's pages HBM->VMEM — no pointer
chasing, no gather materialization. Fork/Join never copy KV: they only
edit the host-side page table this kernel consumes.

Layout:
  q           (B, NKV, G, HD)   one token per stream, GQA groups
  k/v pool    (n_pages, page_size, NKV, HD)
  pool_pos    (n_pages, page_size) int32  adaptive position per slot
  page_table  (B, P_max) int32   prefetched
  page_valid  (B, P_max) int32   tokens used in each page (0 = unused)
  q_pos       (B,) int32         prefetched

Grid (B, NKV, P_max) with the page axis innermost (arbitrary semantics),
running-softmax scratch in VMEM.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_page_step(
    load_kv, page_valid_ref, q_pos_ref, q_ref, pos_page_ref,
    o_ref, m_ref, l_ref, acc_ref,
    *, page_size: int, n_pages_max: int, scale: float, window: int,
):
    """Shared running-softmax body over one streamed page.

    ``load_kv()`` returns the page's K/V as float32 ``(page, HD)`` —
    the f32 kernel casts, the int8 kernel dequantizes with its page
    scales. Only invoked under ``n_valid > 0``, so a skipped page never
    pays the dequant."""
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    n_valid = page_valid_ref[b, pi]

    @pl.when(n_valid > 0)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (G, HD)
        k, v = load_kv()                                  # (page, HD) f32
        kv_pos = pos_page_ref[0]                          # (page,)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, page)
        i = jax.lax.broadcasted_iota(jnp.int32, (page_size,), 0)
        visible = (i < n_valid) & (kv_pos <= q_pos_ref[b])
        if window > 0:
            diff = q_pos_ref[b] - kv_pos
            visible = visible & (diff >= 0) & (diff < window)
        s = jnp.where(visible[None, :], s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        # explicit zero for masked entries: if every entry seen so far is
        # masked, m_new == NEG_INF and exp(s - m_new) would be 1, not 0
        p = jnp.where(visible[None, :], jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(pi == n_pages_max - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _paged_decode_kernel(
    # scalar prefetch
    page_table_ref, page_valid_ref, q_pos_ref,
    # tensors
    q_ref,        # (1, 1, G, HD)
    k_page_ref,   # (1, page_size, 1, HD)
    v_page_ref,
    pos_page_ref,  # (1, page_size)
    # out
    o_ref,        # (1, 1, G, HD)
    # scratch
    m_ref, l_ref, acc_ref,
    *, page_size: int, n_pages_max: int, scale: float, window: int,
):
    def load_kv():
        return (k_page_ref[0, :, 0].astype(jnp.float32),
                v_page_ref[0, :, 0].astype(jnp.float32))

    _flash_page_step(
        load_kv, page_valid_ref, q_pos_ref, q_ref, pos_page_ref,
        o_ref, m_ref, l_ref, acc_ref, page_size=page_size,
        n_pages_max=n_pages_max, scale=scale, window=window)


def _paged_decode_kernel_int8(
    # scalar prefetch
    page_table_ref, page_valid_ref, q_pos_ref,
    # tensors
    q_ref,          # (1, 1, G, HD)
    k_page_ref,     # (1, page_size, 1, HD) int8
    v_page_ref,
    k_scale_ref,    # (1, 1) f32 — this page's absmax scale for head h
    v_scale_ref,
    pos_page_ref,   # (1, page_size)
    # out
    o_ref,          # (1, 1, G, HD)
    # scratch
    m_ref, l_ref, acc_ref,
    *, page_size: int, n_pages_max: int, scale: float, window: int,
):
    """Int8-aware variant: identical flash schedule, but each streamed
    page dequantizes in VMEM (``int8 * page_scale``) before the f32
    accumulation — HBM traffic is a quarter of the f32 kernel's."""
    def load_kv():
        k = k_page_ref[0, :, 0].astype(jnp.float32) * k_scale_ref[0, 0]
        v = v_page_ref[0, :, 0].astype(jnp.float32) * v_scale_ref[0, 0]
        return k, v

    _flash_page_step(
        load_kv, page_valid_ref, q_pos_ref, q_ref, pos_page_ref,
        o_ref, m_ref, l_ref, acc_ref, page_size=page_size,
        n_pages_max=n_pages_max, scale=scale, window=window)


def paged_decode_attention_kernel(
    q: jnp.ndarray,           # (B, NKV, G, HD)
    k_pool: jnp.ndarray,      # (n_pages, page_size, NKV, HD)
    v_pool: jnp.ndarray,
    pool_pos: jnp.ndarray,    # (n_pages, page_size) int32
    page_table: jnp.ndarray,  # (B, P_max) int32
    page_valid: jnp.ndarray,  # (B, P_max) int32
    q_pos: jnp.ndarray,       # (B,) int32
    *, window: int = 0, interpret: bool = False,
    k_scale: jnp.ndarray = None,  # (n_pages, NKV) f32 — int8 pool only
    v_scale: jnp.ndarray = None,
) -> jnp.ndarray:
    b, nkv, g, hd = q.shape
    n_pages, page_size = k_pool.shape[:2]
    p_max = page_table.shape[1]
    scale = 1.0 / math.sqrt(hd)
    quantized = k_scale is not None
    kernel = functools.partial(
        _paged_decode_kernel_int8 if quantized else _paged_decode_kernel,
        page_size=page_size, n_pages_max=p_max,
        scale=scale, window=window,
    )
    kv_spec = pl.BlockSpec(
        (1, page_size, 1, hd),
        lambda b_, h, pi, pt, pv, qp: (pt[b_, pi], 0, h, 0))
    in_specs = [
        pl.BlockSpec((1, 1, g, hd),
                     lambda b_, h, pi, pt, pv, qp: (b_, h, 0, 0)),
        # the page streamed in is chosen BY the prefetched table
        kv_spec,
        kv_spec,
    ]
    operands = [q, k_pool, v_pool]
    if quantized:
        # per-(page, head) scale rides the same table-driven index map
        scale_spec = pl.BlockSpec(
            (1, 1), lambda b_, h, pi, pt, pv, qp: (pt[b_, pi], h))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]
    in_specs.append(pl.BlockSpec(
        (1, page_size), lambda b_, h, pi, pt, pv, qp: (pt[b_, pi], 0)))
    operands.append(pool_pos)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, nkv, p_max),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda b_, h, pi, pt, pv, qp: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nkv, g, hd), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), page_valid.astype(jnp.int32),
      q_pos.astype(jnp.int32), *operands)
