"""Jitted wrapper for paged decode attention."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import paged_decode_attention_kernel


@partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(
    q: jnp.ndarray,           # (B, NH, HD) model layout
    k_pool: jnp.ndarray,      # (n_pages, page_size, NKV, HD)
    v_pool: jnp.ndarray,
    pool_pos: jnp.ndarray,    # (n_pages, page_size)
    page_table: jnp.ndarray,  # (B, P_max)
    page_valid: jnp.ndarray,  # (B, P_max)
    q_pos: jnp.ndarray,       # (B,)
    *, window: int = 0, interpret: bool = True,
) -> jnp.ndarray:
    """Returns (B, NH, HD)."""
    b, nh, hd = q.shape
    nkv = k_pool.shape[2]
    g = nh // nkv
    qg = q.reshape(b, nkv, g, hd)
    out = paged_decode_attention_kernel(
        qg, k_pool, v_pool, pool_pos, page_table, page_valid, q_pos,
        window=window, interpret=interpret)
    return out.reshape(b, nh, hd)
