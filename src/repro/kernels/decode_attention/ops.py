"""Jitted wrappers for paged decode attention.

Two entry points:

* :func:`paged_decode_attention` — kernel-native paged layout
  ``(n_pages, page_size, NKV, HD)``.
* :func:`paged_decode_attention_flat` — engine-native layout: one layer
  of the engine's flat slot pool ``(n_slots, NKV, HD)`` plus the shared
  ``pool_pos`` vector. The flat pool is reinterpreted as pages with a
  free reshape (``n_slots = n_pages * page_size`` by construction), so
  the engine's index chains drive the kernel without any gather or
  copy — the page table rows are built host-side from the chains.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import paged_decode_attention_kernel
from .ref import paged_decode_attention_ref


@partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(
    q: jnp.ndarray,           # (B, NH, HD) model layout
    k_pool: jnp.ndarray,      # (n_pages, page_size, NKV, HD)
    v_pool: jnp.ndarray,
    pool_pos: jnp.ndarray,    # (n_pages, page_size)
    page_table: jnp.ndarray,  # (B, P_max)
    page_valid: jnp.ndarray,  # (B, P_max)
    q_pos: jnp.ndarray,       # (B,)
    *, window: int = 0, interpret: bool = True,
    k_scale: jnp.ndarray = None,  # (n_pages, NKV) — int8 pools only
    v_scale: jnp.ndarray = None,
) -> jnp.ndarray:
    """Returns (B, NH, HD). With an int8 pool, pass the per-page-per-head
    absmax scales and the int8 kernel variant dequantizes in VMEM."""
    b, nh, hd = q.shape
    nkv = k_pool.shape[2]
    g = nh // nkv
    qg = q.reshape(b, nkv, g, hd)
    out = paged_decode_attention_kernel(
        qg, k_pool, v_pool, pool_pos, page_table, page_valid, q_pos,
        window=window, interpret=interpret,
        k_scale=k_scale, v_scale=v_scale)
    return out.reshape(b, nh, hd)


@partial(jax.jit, static_argnames=("window",))
def paged_decode_attention_xla(
    q: jnp.ndarray,           # (B, NH, HD) model layout
    k_pool: jnp.ndarray,      # (n_pages, page_size, NKV, HD)
    v_pool: jnp.ndarray,
    pool_pos: jnp.ndarray,    # (n_pages, page_size)
    page_table: jnp.ndarray,  # (B, P_max)
    page_valid: jnp.ndarray,  # (B, P_max)
    q_pos: jnp.ndarray,       # (B,)
    *, window: int = 0,
) -> jnp.ndarray:
    """Pure-XLA execution of the paged-attention schedule (no Pallas).

    Same contract and same math as the Mosaic kernel: gather whole
    *pages* via the page table (contiguous block reads — this is the
    schedule's memory-access advantage over a per-token slot gather,
    and it is measurable even on CPU), then masked softmax over the
    per-page valid prefixes. This is the portable fallback tier for
    backends without Mosaic, and what ``benchmarks/kernel_bench.py``
    times on CPU, where ``interpret=True`` is a correctness emulation
    with no performance meaning. Returns (B, NH, HD) in float32.
    """
    b, nh, hd = q.shape
    nkv = k_pool.shape[2]
    out = paged_decode_attention_ref(
        q.reshape(b, nkv, nh // nkv, hd), k_pool, v_pool, pool_pos,
        page_table, page_valid, q_pos, window=window)
    return out.reshape(b, nh, hd)


@partial(jax.jit, static_argnames=("page_size", "window", "interpret"))
def paged_decode_attention_flat(
    q: jnp.ndarray,           # (B, NH, HD) model layout
    k_slots: jnp.ndarray,     # (n_slots, NKV, HD) one layer of the pool
    v_slots: jnp.ndarray,
    pool_pos: jnp.ndarray,    # (n_slots,)
    page_table: jnp.ndarray,  # (B, P_max) page ids per stream chain
    page_valid: jnp.ndarray,  # (B, P_max) referenced slots per page
    q_pos: jnp.ndarray,       # (B,)
    *, page_size: int, window: int = 0, interpret: bool = True,
    k_scale: jnp.ndarray = None,  # (n_pages, NKV) — int8 pools only
    v_scale: jnp.ndarray = None,
) -> jnp.ndarray:
    """Paged decode attention over the engine's flat slot pool.

    ``k_slots``/``v_slots`` are one layer of the engine pool (flat slot
    axis); the reshape to ``(n_pages, page_size, ...)`` is metadata-only.
    ``page_table[b]`` lists the pages of stream b's index chain in
    first-appearance order and ``page_valid[b]`` how many leading slots
    of each page the chain references (engine chains always reference a
    contiguous slot prefix of every page they touch — pages are
    single-writer and append-only). Returns (B, NH, HD). ``k_scale``/
    ``v_scale`` select the int8 kernel variant (dequant in VMEM).
    """
    n_slots = k_slots.shape[0]
    assert n_slots % page_size == 0, (n_slots, page_size)
    n_pages = n_slots // page_size
    kp = k_slots.reshape(n_pages, page_size, *k_slots.shape[1:])
    vp = v_slots.reshape(n_pages, page_size, *v_slots.shape[1:])
    pp = pool_pos.reshape(n_pages, page_size)
    return paged_decode_attention(
        q, kp, vp, pp, page_table, page_valid, q_pos,
        window=window, interpret=interpret,
        k_scale=k_scale, v_scale=v_scale)
