"""Pallas TPU kernel: RWKV-6 WKV recurrence with data-dependent decay,

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t

blocked as (batch, head, seq-chunk): the (n, n) per-head state lives in
VMEM scratch across chunks; each timestep is a VPU outer-product update
(n = 64 for rwkv6-3b — a (64, 64) f32 tile).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref,
                state_ref, *, chunk: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0].astype(jnp.float32)   # (chunk, n)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    w = w_ref[0, :, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)         # (n,)

    def step(t, state):
        kv = k[t][:, None] * v[t][None, :]                 # (n, n)
        y = jnp.einsum("ij,i->j", state + u[:, None] * kv, r[t])
        y_ref[0, t, 0] = y.astype(y_ref.dtype)
        return w[t][:, None] * state + kv

    state_ref[...] = jax.lax.fori_loop(0, chunk, step, state_ref[...])


def rwkv6_scan_kernel(
    r: jnp.ndarray,   # (B, S, H, n)
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,   # decay in (0,1)
    u: jnp.ndarray,   # (H, n) bonus
    s0: jnp.ndarray,  # (B, H, n, n) initial state
    *, chunk: int = 64, interpret: bool = False,
) -> jnp.ndarray:
    bsz, s, h, n = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    grid = (bsz, h, s // chunk)
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    seq_spec = pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, si: (b_, si, h_, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, n), lambda b_, h_, si: (h_, 0)),
            pl.BlockSpec((1, 1, n, n), lambda b_, h_, si: (b_, h_, 0, 0)),
        ],
        out_specs=seq_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, s, h, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
