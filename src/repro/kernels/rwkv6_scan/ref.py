"""Oracle: sequential WKV scan (same math as models.rwkv.wkv_scan_ref)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, w, u, s0):
    """r,k,v,w: (B,S,H,n); u: (H,n); s0: (B,H,n,n) -> y (B,S,H,n)."""
    rs = r.astype(jnp.float32).transpose(1, 0, 2, 3)
    ks = k.astype(jnp.float32).transpose(1, 0, 2, 3)
    vs = v.astype(jnp.float32).transpose(1, 0, 2, 3)
    ws = w.astype(jnp.float32).transpose(1, 0, 2, 3)
    u = u.astype(jnp.float32)

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhij,bhi->bhj", state + u[..., :, None] * kv, r_t)
        return w_t[..., :, None] * state + kv, y

    _, ys = jax.lax.scan(step, s0.astype(jnp.float32), (rs, ks, vs, ws))
    return ys.transpose(1, 0, 2, 3)
