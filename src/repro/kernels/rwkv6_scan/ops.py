"""Jitted wrapper for the RWKV-6 WKV kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import rwkv6_scan_kernel


def _pick(n: int, target: int) -> int:
    if n % target == 0:
        return target
    for c in (32, 16, 8, 4, 2, 1):
        if n % c == 0:
            return c
    return 1


@partial(jax.jit, static_argnames=("interpret",))
def rwkv6_scan(r, k, v, w, u, s0=None, *, interpret: bool = True):
    bsz, s, h, n = r.shape
    if s0 is None:
        s0 = jnp.zeros((bsz, h, n, n), jnp.float32)
    return rwkv6_scan_kernel(r, k, v, w, u, s0,
                             chunk=_pick(s, 64), interpret=interpret)
