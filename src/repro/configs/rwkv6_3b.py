"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960
vocab=65536 — Finch, data-dependent decay [arXiv:2404.05892].

Attention-free: the MedVerse attention mask is inapplicable (DESIGN.md
§4); the engine-level fork/join (state copy / re-scan) still applies.
long_500k eligible: O(1) recurrent state.
"""

import dataclasses

from ..models.config import RWKV6, ModelConfig, RWKV6Config

FULL = ModelConfig(
    name="rwkv6-3b",
    arch_type="ssm",
    vocab_size=65536,
    d_model=2560,
    n_layers=32,
    n_heads=40,                  # d_model / head_dim bookkeeping
    n_kv_heads=40,
    d_ff=8960,
    head_dim=64,
    pattern_unit=(RWKV6,),
    pos_embedding="none",        # rwkv has no positional embedding
    rwkv=RWKV6Config(head_dim=64, decay_lora=64, mix_lora=32),
    medverse_attention=False,    # engine-level parallelism only
    long_context_ok=True,
    dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    FULL,
    name="rwkv6-3b-smoke",
    vocab_size=512,
    d_model=256,
    n_layers=2,
    n_heads=4,
    n_kv_heads=4,
    head_dim=64,
    d_ff=512,
    rwkv=RWKV6Config(head_dim=64, decay_lora=16, mix_lora=8),
    dtype="float32",
    remat=False,
)
