"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

Shapes (assigned):
    train_4k     seq_len=4096    global_batch=256  (training)
    prefill_32k  seq_len=32768   global_batch=32   (inference-prefill)
    decode_32k   seq_len=32768   global_batch=128  (inference-decode)
    long_500k    seq_len=524288  global_batch=1    (long-context-decode)

Decode shapes lower ``serve_step`` (ONE token against a KV cache of
seq_len), not ``train_step``. ``input_specs`` never allocates — pure
ShapeDtypeStruct, weak-type-correct and shardable.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def topo_specs(b: int, s: int) -> Dict[str, jax.ShapeDtypeStruct]:
    return {
        "seg_id": sds((b, s), jnp.int32),
        "layer_id": sds((b, s), jnp.int32),
        "pos_id": sds((b, s), jnp.int32),
    }


def train_input_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    b, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": sds((b, s), jnp.int32),
        "targets": sds((b, s), jnp.int32),
        "loss_mask": sds((b, s), jnp.float32),
        **topo_specs(b, s),
    }
    if cfg.vision is not None:
        d = cfg.vision.embed_dim or cfg.d_model
        specs["image_embeds"] = sds((b, cfg.vision.n_image_tokens, d), cfg.dtype)
    if cfg.encoder is not None:
        specs["audio_embeds"] = sds((b, cfg.encoder.n_ctx, cfg.d_model), cfg.dtype)
    return specs


def decode_input_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    """serve_step inputs: one new token per stream + stream metadata.
    The KV cache itself is an explicit (donated) argument built by
    ``models.init_cache`` as ShapeDtypeStructs in the dry-run."""
    b = shape.global_batch
    return {
        "token_t": sds((b,), jnp.int32),
        "q_pos": sds((b,), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    """ShapeDtypeStruct mirror of models.init_cache (no allocation)."""
    from ..models.transformer import init_cache

    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len)
    )
