from .registry import ARCH_IDS, ASSIGNED_ARCH_IDS, all_configs, get_config
from .shapes import SHAPES, InputShape, decode_input_specs, train_input_specs

__all__ = [
    "ARCH_IDS",
    "ASSIGNED_ARCH_IDS",
    "all_configs",
    "get_config",
    "SHAPES",
    "InputShape",
    "decode_input_specs",
    "train_input_specs",
]
