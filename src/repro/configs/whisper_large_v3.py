"""whisper-large-v3 [audio]: 32L d_model=1280 20H (kv=20) d_ff=5120
vocab=51866 — enc-dec, conv frontend STUBBED [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is a stub: ``input_specs``
provides precomputed frame embeddings (B, 1500, 1280). We implement the
full 32L bidirectional encoder + 32L decoder with cross-attention.
Decoder positions are learned (whisper style); the model card caps
decoder context at 448 — the 32k decode shape exercises the cache
machinery structurally (noted in DESIGN.md). long_500k skipped.
"""

import dataclasses

from ..models.config import ATTN, EncoderConfig, ModelConfig

FULL = ModelConfig(
    name="whisper-large-v3",
    arch_type="audio",
    vocab_size=51866,
    d_model=1280,
    n_layers=32,                 # decoder layers
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    head_dim=64,
    pattern_unit=(ATTN,),
    pos_embedding="learned",
    mlp_activation="gelu",
    norm_type="layernorm",
    encoder=EncoderConfig(n_layers=32, n_ctx=1500),
    max_seq_len=32768,           # learned pos table sized for decode_32k
    dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    FULL,
    name="whisper-large-v3-smoke",
    vocab_size=512,
    d_model=256,
    n_layers=2,
    n_heads=4,
    n_kv_heads=4,
    head_dim=64,
    d_ff=512,
    encoder=EncoderConfig(n_layers=2, n_ctx=16),
    max_seq_len=64,
    dtype="float32",
    remat=False,
)
