"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, 1 attn : 2 recurrent
[arXiv:2402.19427].

Pattern: (rglru, rglru, local_attn) x 8 + (rglru, rglru) tail = 26.
long_500k eligible: recurrent state is O(1); attention is window-2048.
"""

import dataclasses

from ..models.config import LOCAL_ATTN, RGLRU, ModelConfig, RGLRUConfig

FULL = ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    vocab_size=256000,
    d_model=2560,
    n_layers=26,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    head_dim=256,
    pattern_unit=(RGLRU, RGLRU, LOCAL_ATTN),
    tail=(RGLRU, RGLRU),
    sliding_window=2048,         # griffin local attention window
    rglru=RGLRUConfig(lru_width=2560, conv1d_width=4, n_heads=10),
    tie_embeddings=True,
    long_context_ok=True,
    dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    FULL,
    name="recurrentgemma-2b-smoke",
    vocab_size=512,
    d_model=256,
    n_layers=3,
    n_heads=4,
    n_kv_heads=1,
    head_dim=64,
    d_ff=512,
    pattern_unit=(RGLRU, RGLRU, LOCAL_ATTN),
    tail=(),
    sliding_window=8,
    rglru=RGLRUConfig(lru_width=256, conv1d_width=4, n_heads=4),
    dtype="float32",
    remat=False,
)
