"""qwen3-32b [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936 — qk_norm, GQA [hf:Qwen/Qwen3-8B family]."""

import dataclasses

from ..models.config import ATTN, ModelConfig

FULL = ModelConfig(
    name="qwen3-32b",
    arch_type="dense",
    vocab_size=151936,
    d_model=5120,
    n_layers=64,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    head_dim=128,
    pattern_unit=(ATTN,),
    qk_norm=True,                # qwen3 per-head RMS q/k norm
    rope_theta=1_000_000.0,
    dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    FULL,
    name="qwen3-32b-smoke",
    vocab_size=512,
    d_model=256,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    d_ff=512,
    dtype="float32",
    remat=False,
)
