"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16 experts top-4, fine-grained [hf:databricks/dbrx-base]."""

import dataclasses

from ..models.config import ATTN, ModelConfig, MoEConfig

FULL = ModelConfig(
    name="dbrx-132b",
    arch_type="moe",
    vocab_size=100352,
    d_model=6144,
    n_layers=40,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    head_dim=128,
    pattern_unit=(ATTN,),
    norm_type="layernorm",       # dbrx uses LayerNorm
    rope_theta=500_000.0,
    moe=MoEConfig(
        n_experts=16,
        top_k=4,
        d_ff_expert=10752,
        router_scoring="softmax",
    ),
    dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    FULL,
    name="dbrx-132b-smoke",
    vocab_size=512,
    d_model=256,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    d_ff=512,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=256,
                  router_scoring="softmax", capacity_factor=2.0),
    dtype="float32",
    remat=False,
)
