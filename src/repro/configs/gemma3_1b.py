"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144 — 5:1 local:global interleave, 128k context
[hf:google/gemma-3-1b-pt].

Pattern: (local x5, global) x 4 + (local x2) tail = 26 layers.
long_500k eligible: 24/26 layers are window-512 sliding attention; the 4
global layers decode linearly against the long cache.
"""

import dataclasses

from ..models.config import ATTN, LOCAL_ATTN, ModelConfig

FULL = ModelConfig(
    name="gemma3-1b",
    arch_type="dense",
    vocab_size=262144,
    d_model=1152,
    n_layers=26,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    head_dim=256,
    pattern_unit=(LOCAL_ATTN,) * 5 + (ATTN,),
    tail=(LOCAL_ATTN, LOCAL_ATTN),
    sliding_window=512,          # gemma3-1b local window
    qk_norm=True,                # gemma3 uses q/k norm
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    logit_softcap=30.0,
    long_context_ok=True,
    dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    FULL,
    name="gemma3-1b-smoke",
    vocab_size=512,
    d_model=256,
    n_layers=2,
    n_heads=4,
    n_kv_heads=1,
    head_dim=64,
    d_ff=512,
    pattern_unit=(LOCAL_ATTN, ATTN),
    tail=(),
    sliding_window=8,
    dtype="float32",
    remat=False,
)
