"""llama3.2-1b [dense]: 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256 — small llama3 [hf:meta-llama/Llama-3.2-1B]."""

import dataclasses

from ..models.config import ATTN, ModelConfig

FULL = ModelConfig(
    name="llama3.2-1b",
    arch_type="dense",
    vocab_size=128256,
    d_model=2048,
    n_layers=16,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    head_dim=64,
    pattern_unit=(ATTN,),
    rope_theta=500_000.0,
    tie_embeddings=True,
    dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    FULL,
    name="llama3.2-1b-smoke",
    vocab_size=512,
    d_model=256,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    d_ff=512,
    dtype="float32",
    remat=False,
)
