"""deepseek-v3-671b [moe]: 61L d_model=7168 128H (MLA) d_ff=2048 (expert)
vocab=129280, MoE 1 shared + 256 routed top-8, MTP [arXiv:2412.19437].

MLA: q_lora 1536 / kv_lora 512 / nope 128 / rope 64 / v 128 per the
paper. First 3 layers dense (d_ff 18432). Sigmoid router scoring with
in-group renormalization; we use a standard aux loss in place of the
paper's bias-based aux-free balancing (recorded deviation, DESIGN.md §6).
long_500k skipped: full attention (albeit with compressed KV).
"""

import dataclasses

from ..models.config import ATTN, MLAConfig, ModelConfig, MoEConfig

FULL = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    vocab_size=129280,
    d_model=7168,
    n_layers=61,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,
    head_dim=128,
    pattern_unit=(ATTN,),
    rope_theta=10_000.0,
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        d_ff_expert=2048,
        n_shared_experts=1,
        d_ff_shared=2048,
        first_dense_layers=3,
        d_ff_dense=18432,
        router_scoring="sigmoid",
    ),
    mtp_depth=1,
    dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    FULL,
    name="deepseek-v3-671b-smoke",
    vocab_size=512,
    d_model=256,
    n_layers=2,
    n_heads=4,
    n_kv_heads=4,
    head_dim=64,
    d_ff=256,
    mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
                  qk_rope_head_dim=16, v_head_dim=32),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128,
                  n_shared_experts=1, d_ff_shared=128,
                  first_dense_layers=1, d_ff_dense=512,
                  router_scoring="sigmoid", capacity_factor=2.0),
    mtp_depth=1,
    dtype="float32",
    remat=False,
)
