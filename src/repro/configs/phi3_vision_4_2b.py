"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064 — phi3-mini backbone + CLIP frontend STUBBED
[hf:microsoft/Phi-3-vision-128k-instruct].

The ViT/projector is a stub: ``input_specs`` supplies patch embeddings
(B, 576, 1024) which a learned projector maps to d_model and interleaves
as the sequence prefix (image tokens are in-degree-0 source places in
the Petri net). long_500k skipped: full attention.
"""

import dataclasses

from ..models.config import ATTN, ModelConfig, VisionConfig

FULL = ModelConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    vocab_size=32064,
    d_model=3072,
    n_layers=32,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    head_dim=96,
    pattern_unit=(ATTN,),
    rope_theta=10_000.0,
    vision=VisionConfig(n_image_tokens=576, embed_dim=1024),
    dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    FULL,
    name="phi-3-vision-4.2b-smoke",
    vocab_size=512,
    d_model=256,
    n_layers=2,
    n_heads=4,
    n_kv_heads=4,
    head_dim=64,
    d_ff=512,
    vision=VisionConfig(n_image_tokens=8, embed_dim=64),
    dtype="float32",
    remat=False,
)
