"""medverse-7b — the paper's own instantiation: Qwen2.5-7B-Instruct
backbone shape (28L d_model=3584 28H kv=4 d_ff=18944 vocab=152064) with
MedVerse attention [paper Sec. 5.1; hf:Qwen/Qwen2.5-7B-Instruct]."""

import dataclasses

from ..models.config import ATTN, ModelConfig

FULL = ModelConfig(
    name="medverse-7b",
    arch_type="dense",
    vocab_size=152064,
    d_model=3584,
    n_layers=28,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    head_dim=128,
    pattern_unit=(ATTN,),
    rope_theta=1_000_000.0,
    medverse_attention=True,
    dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    FULL,
    name="medverse-7b-smoke",
    vocab_size=512,
    d_model=256,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    d_ff=512,
    dtype="float32",
    remat=False,
)
