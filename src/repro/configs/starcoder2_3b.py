"""starcoder2-3b [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE [arXiv:2402.19173]."""

import dataclasses

from ..models.config import ATTN, ModelConfig

FULL = ModelConfig(
    name="starcoder2-3b",
    arch_type="dense",
    vocab_size=49152,
    d_model=3072,
    n_layers=30,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    head_dim=128,
    pattern_unit=(ATTN,),
    mlp_activation="gelu",       # starcoder2 uses gelu MLP
    norm_type="layernorm",       # and LayerNorm
    rope_theta=999_999.0,        # arXiv:2402.19173 rope base
    dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    FULL,
    name="starcoder2-3b-smoke",
    vocab_size=512,
    d_model=256,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    d_ff=512,
    dtype="float32",
    remat=False,
)
