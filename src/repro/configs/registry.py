"""Architecture registry: ``get_config(arch_id, smoke=False)``.

Every assigned architecture (plus the paper's own medverse-7b backbone)
is selectable by id — the ``--arch <id>`` surface of the launcher.
"""

from __future__ import annotations

from typing import Dict, List

from ..models.config import ModelConfig, validate_config
from . import (
    dbrx_132b,
    deepseek_v3_671b,
    gemma3_1b,
    llama3_2_1b,
    medverse_7b,
    phi3_vision_4_2b,
    qwen3_32b,
    recurrentgemma_2b,
    rwkv6_3b,
    starcoder2_3b,
    whisper_large_v3,
)

_MODULES = {
    "starcoder2-3b": starcoder2_3b,
    "qwen3-32b": qwen3_32b,
    "gemma3-1b": gemma3_1b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "whisper-large-v3": whisper_large_v3,
    "phi-3-vision-4.2b": phi3_vision_4_2b,
    "rwkv6-3b": rwkv6_3b,
    "llama3.2-1b": llama3_2_1b,
    "dbrx-132b": dbrx_132b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "medverse-7b": medverse_7b,
}

ARCH_IDS: List[str] = list(_MODULES.keys())
ASSIGNED_ARCH_IDS: List[str] = [a for a in ARCH_IDS if a != "medverse-7b"]


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch '{arch_id}'; known: {ARCH_IDS}")
    cfg = _MODULES[arch_id].SMOKE if smoke else _MODULES[arch_id].FULL
    validate_config(cfg)
    return cfg


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}
