"""Speculative decoding drafters for the MedVerse engine.

A :class:`Drafter` proposes cheap draft continuations for a decode
stream; the engine verifies up to ``EngineConfig.draft_len`` of them in
the *same* batched ``paged_decode`` call that would have decoded one
token (draft tokens occupy otherwise-idle batch rows, and the
position mask ``kv_pos <= q_pos`` hides each row's successors), then
commits the longest accepted prefix and rolls the rejected slots back
(:meth:`..kvcache.IndexChain.pop_slot`). Because every live stream
drafts independently, a wide DAG frontier speculates on every branch at
once — DAG width × draft depth, the multiplier a linear engine never
gets.

Both built-in drafters are model-free (no draft model, no extra
forward passes — proposals are host-side lookups over already-decoded
text):

* :class:`NgramDrafter` — prompt-lookup drafting: match the stream's
  trailing n-gram against its own history first, then against a global
  index of recently finished streams, and propose whatever followed
  the most recent prior occurrence. Strong whenever decoded text is
  self-similar or requests repeat.
* :class:`RadixDrafter` — radix-continuation drafting: walk the
  engine's radix prefix cache along the stream's *full* token history
  and propose the cached continuation. The engine (when this drafter
  is active) inserts finished linear streams into the radix tree, so a
  repeated request replays its predecessor's exact decode — 100%
  acceptance at temperature 0.

Correctness contract (pinned by ``tests/test_spec_decode.py``): a draft
token is accepted only if it equals the argmax of the verified logits
at its position, so temperature-0 output text is bit-identical with
speculation on or off — drafters only change *how many* decode
iterations that text costs, never what it is. Drafting is disabled for
temperature>0 streams (forced-token batching still applies — it is
distribution-free).
"""

from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from .radix import RadixTree

DRAFTERS = ("ngram", "radix")


class Drafter:
    """Interface the engine drafts through.

    Invariants the engine relies on:

    * :meth:`propose` is a pure lookup — it must not mutate pool pages,
      chains, or the radix tree, and it may return fewer than ``k``
      tokens (including none). Proposals are *hints*: every one is
      verified against the target model before it can be committed, so
      a wrong proposal costs only the batch row it occupied.
    * :meth:`observe` is called once per finished stream with the
      stream's committed token sequence (prompt/ancestor history plus
      generated tokens when the ancestry is linear, generated tokens
      alone otherwise). It must tolerate arbitrary sequences.
    """

    name = "base"
    #: True if the engine should insert finished linear streams into the
    #: radix prefix cache so this drafter can read them back.
    wants_generation_cache = False

    def observe(self, tokens: Sequence[int]) -> None:
        """Index a finished stream's committed tokens as draft source."""

    def propose(self, ctx: Sequence[int], k: int) -> List[int]:
        """Up to ``k`` draft tokens continuing ``ctx`` (may be empty)."""
        raise NotImplementedError


class NgramDrafter(Drafter):
    """Prompt-lookup drafting (PLD-style, no draft model).

    ``propose`` matches the last ``order`` tokens of the context (falling
    back to shorter n-grams down to ``min_order``) against two sources,
    longest match first and, at equal order, cross-request evidence
    first:

    1. a global index over the last ``max_sequences`` finished streams
       (:meth:`observe`) — repeated or near-duplicate requests replay
       each other's decodes;
    2. the context itself — the most recent *prior* occurrence of the
       trailing n-gram; whatever followed it is the proposal (decoded
       text, headers, and plans are highly self-similar).
    """

    name = "ngram"

    def __init__(self, order: int = 8, min_order: int = 4,
                 max_sequences: int = 64):
        assert order >= min_order >= 1
        self.order = order
        self.min_order = min_order
        self._seqs: Deque[List[int]] = deque(maxlen=max_sequences)
        # (n, ngram) -> (sequence, end-of-match index); newest insert wins
        self._index: Dict[Tuple[int, ...], Tuple[List[int], int]] = {}

    def observe(self, tokens: Sequence[int]) -> None:
        seq = [int(t) for t in tokens]
        if len(seq) <= self.min_order:
            return
        if len(self._seqs) == self._seqs.maxlen:
            old = self._seqs[0]
            for key in self._grams(old):
                ref = self._index.get(key)
                if ref is not None and ref[0] is old:
                    del self._index[key]
        self._seqs.append(seq)
        for key, end in self._grams(seq, with_pos=True):
            self._index[key] = (seq, end)

    def _grams(self, seq: List[int], with_pos: bool = False):
        for n in range(self.min_order, self.order + 1):
            for i in range(len(seq) - n):
                key = (n, *seq[i: i + n])
                yield (key, i + n) if with_pos else key

    def propose(self, ctx: Sequence[int], k: int) -> List[int]:
        ctx = [int(t) for t in ctx]
        for n in range(self.order, self.min_order - 1, -1):
            if len(ctx) < n:
                continue
            tail = ctx[-n:]
            # 1) global index over finished streams: a repeated request
            # replays its predecessor's exact decode, so cross-request
            # evidence beats a coincidental self-match at equal order
            ref = self._index.get((n, *tail))
            if ref is not None:
                seq, end = ref
                out = seq[end: end + k]
                if out:
                    return out
            # 2) self-context: most recent prior occurrence
            for i in range(len(ctx) - n - 1, -1, -1):
                if ctx[i: i + n] == tail:
                    out = ctx[i + n: i + n + k]
                    if out:
                        return out
        return []


class RadixDrafter(Drafter):
    """Radix-continuation drafting over the engine's prefix cache.

    Walks the shared :class:`~.radix.RadixTree` along the stream's full
    token history (prompt + committed decode) and proposes the cached
    continuation (``RadixTree.continuation``). Only streams with linear,
    sequentially-positioned ancestry are inserted into the tree (the
    engine enforces this — see ``MedVerseEngine._observe_stream``), so
    every cached path is also a valid prefill prefix: draft source and
    prefix cache stay one structure, one eviction policy.
    """

    name = "radix"
    wants_generation_cache = True

    def __init__(self, tree: RadixTree):
        self.tree = tree

    def propose(self, ctx: Sequence[int], k: int) -> List[int]:
        if not ctx:
            return []
        return self.tree.continuation(list(ctx), k)


def make_drafter(name: str, radix: Optional[RadixTree] = None) -> Drafter:
    """Construct the drafter ``EngineConfig.drafter`` names."""
    if name == "ngram":
        return NgramDrafter()
    if name == "radix":
        if radix is None:
            raise ValueError("radix drafter requires the engine radix tree "
                             "(EngineConfig.radix_cache=True)")
        return RadixDrafter(radix)
    raise ValueError(f"drafter={name!r}: expected one of {DRAFTERS}")
