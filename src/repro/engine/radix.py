"""Radix (prefix) tree over token sequences for cross-request KV reuse.

The paper's engine "leverag[es] Radix Attention [SGLang] for zero-copy
forking". Within one request, forking needs no lookup (the child copies
the parent's index chain — see kvcache.IndexChain.fork). The radix tree
adds the *cross-request* reuse: two questions with the same prompt
prefix, or a regenerated branch, share pool slots instead of recomputing
prefill.

Host-side structure; nodes own spans of pool slot indices. Matching is
token-exact. Eviction = LRU leaves with refcount 0.

Page lifetime: when constructed with ``page_size`` and pin callbacks
(the engine passes ``PageAllocator.pin``/``unpin``), every node holds
one cache pin per distinct pool page its slots touch, so cached K/V
survives the originating request's chain release. Evicting a node drops
its pins; the engine wires ``evict_one`` in as the allocator's reclaim
callback, so the cache shrinks automatically under page pressure.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from ..obs.trace import NULL_RECORDER


@dataclasses.dataclass
class RadixNode:
    tokens: List[int]                       # edge label (token ids)
    slots: np.ndarray                       # pool slot per token in edge
    children: Dict[int, "RadixNode"]        # first-token -> child
    parent: Optional["RadixNode"]
    refcount: int = 0
    last_used: float = 0.0

    def is_leaf(self) -> bool:
        return not self.children


class RadixTree:
    def __init__(self, page_size: Optional[int] = None,
                 on_pin: Optional[Callable[[int], None]] = None,
                 on_unpin: Optional[Callable[[int], None]] = None):
        self.root = RadixNode(tokens=[], slots=np.zeros((0,), np.int32),
                              children={}, parent=None, refcount=1)
        self.page_size = page_size
        self._on_pin = on_pin
        self._on_unpin = on_unpin
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.inserts = 0        # insert() calls that added >= 1 node
        # trace hook (engine-attached when EngineConfig.trace is on)
        self.tracer = NULL_RECORDER

    def _pages(self, slots: np.ndarray) -> Set[int]:
        if self.page_size is None:
            return set()
        return {int(s) // self.page_size for s in np.asarray(slots)}

    def _pin(self, pages: Set[int]) -> None:
        if self._on_pin is not None:
            for pg in sorted(pages):
                self._on_pin(pg)

    def _unpin(self, pages: Set[int]) -> None:
        if self._on_unpin is not None:
            for pg in sorted(pages):
                self._on_unpin(pg)

    # -- lookup -------------------------------------------------------------
    def match_prefix(self, tokens: List[int]) -> Tuple[np.ndarray, List[RadixNode]]:
        """Longest cached prefix of ``tokens``. Returns (slot indices,
        path nodes whose refcounts the caller now holds)."""
        node = self.root
        matched: List[np.ndarray] = []
        path: List[RadixNode] = []
        i = 0
        while i < len(tokens):
            child = node.children.get(tokens[i])
            if child is None:
                break
            el = len(child.tokens)
            j = 0
            while j < el and i + j < len(tokens) and child.tokens[j] == tokens[i + j]:
                j += 1
            if j == 0:
                break
            if j < el:
                # partial edge match: split is only needed on insert;
                # for lookup just take the matched half.
                matched.append(child.slots[:j])
                child.refcount += 1
                child.last_used = time.monotonic()
                path.append(child)
                i += j
                break
            matched.append(child.slots)
            child.refcount += 1
            child.last_used = time.monotonic()
            path.append(child)
            node = child
            i += el
        if matched:
            self.hits += 1
        else:
            self.misses += 1
        slots = (np.concatenate(matched).astype(np.int32)
                 if matched else np.zeros((0,), np.int32))
        if self.tracer.enabled:
            self.tracer.instant("radix_hit" if matched else "radix_miss",
                                "radix", n_tokens=len(tokens),
                                n_cached=int(slots.size))
        return slots, path

    def release(self, path: List[RadixNode]) -> None:
        for n in path:
            n.refcount -= 1

    def continuation(self, tokens: List[int], k: int) -> List[int]:
        """Read-only draft lookup: up to ``k`` cached tokens continuing
        ``tokens``.

        Walks the tree along the *entire* ``tokens`` sequence; if the
        walk consumes it all (ending mid-edge or on a node), the
        following edge tokens — descending into the most-recently-used
        child at branch points — are the proposal. A mismatch or
        fall-off before the end returns ``[]``: the cache has never
        seen this history, so it has nothing to say. Unlike
        :meth:`match_prefix` this takes no refcount leases and updates
        no LRU clocks — drafting must not change eviction order.
        """
        node, i = self.root, 0
        out: List[int] = []
        while i < len(tokens):
            child = node.children.get(tokens[i])
            if child is None:
                return []
            el = len(child.tokens)
            j = 0
            while (j < el and i + j < len(tokens)
                   and child.tokens[j] == tokens[i + j]):
                j += 1
            i += j
            if j < el:
                if i < len(tokens):
                    return []        # diverged mid-edge
                out = list(child.tokens[j:])   # rest of the edge
            node = child
        while len(out) < k and node.children:
            node = max(node.children.values(), key=lambda c: c.last_used)
            out.extend(node.tokens)
        return out[:k]

    # -- insert -------------------------------------------------------------
    def insert(self, tokens: List[int], slots: np.ndarray) -> None:
        """Register a decoded sequence's (tokens -> pool slots) mapping."""
        assert len(tokens) == len(slots)
        node = self.root
        i = 0
        while i < len(tokens):
            child = node.children.get(tokens[i])
            if child is None:
                new = RadixNode(
                    tokens=list(tokens[i:]),
                    slots=np.asarray(slots[i:], np.int32),
                    children={}, parent=node,
                    last_used=time.monotonic(),
                )
                node.children[tokens[i]] = new
                self._pin(self._pages(new.slots))
                self.inserts += 1
                if self.tracer.enabled:
                    self.tracer.instant("radix_insert", "radix",
                                        n_tokens=len(new.tokens))
                return
            el = len(child.tokens)
            j = 0
            while j < el and i + j < len(tokens) and child.tokens[j] == tokens[i + j]:
                j += 1
            if j == el:
                node = child
                i += el
                continue
            # split the edge at j; outstanding match-path leases point at
            # the child node object (the prefix half), so the new suffix
            # starts unreferenced — otherwise it could never be evicted
            suffix = RadixNode(
                tokens=child.tokens[j:],
                slots=child.slots[j:],
                children=child.children,
                parent=child,
                refcount=0,
                last_used=child.last_used,
            )
            for gn in suffix.children.values():
                gn.parent = suffix
            child.tokens = child.tokens[:j]
            child.slots = child.slots[:j]
            child.children = {suffix.tokens[0]: suffix}
            # invariant: each node holds one pin per distinct page of its
            # own slots — a page straddling the split point now backs two
            # nodes, so it needs one extra pin
            self._pin(self._pages(child.slots) & self._pages(suffix.slots))
            node = child
            i += j
        # full match: nothing to add

    # -- eviction -----------------------------------------------------------
    def evict_one(self) -> bool:
        """Evict the least-recently-used unreferenced leaf, dropping its
        page pins. Returns True if a node was evicted — the allocator
        calls this repeatedly as its reclaim hook when out of pages."""
        best: Optional[RadixNode] = None
        stack = [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n is self.root or n.children or n.refcount > 0:
                continue
            if best is None or n.last_used < best.last_used:
                best = n
        if best is None:
            return False
        parent = best.parent
        if parent is not None:
            for key, ch in list(parent.children.items()):
                if ch is best:
                    del parent.children[key]
        if self.tracer.enabled:
            self.tracer.instant("radix_evict", "radix",
                                n_tokens=len(best.tokens))
        self._unpin(self._pages(best.slots))
        self.evictions += 1
        return True

    def n_cached_tokens(self) -> int:
        total = 0
        stack = [self.root]
        while stack:
            n = stack.pop()
            total += len(n.tokens)
            stack.extend(n.children.values())
        return total
