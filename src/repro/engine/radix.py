"""Radix (prefix) tree over token sequences for cross-request KV reuse.

The paper's engine "leverag[es] Radix Attention [SGLang] for zero-copy
forking". Within one request, forking needs no lookup (the child copies
the parent's index chain — see kvcache.IndexChain.fork). The radix tree
adds the *cross-request* reuse: two questions with the same prompt
prefix, or a regenerated branch, share pool slots instead of recomputing
prefill.

Host-side structure; nodes own spans of pool slot indices. Matching is
token-exact. Eviction = LRU leaves with refcount 0.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class RadixNode:
    tokens: List[int]                       # edge label (token ids)
    slots: np.ndarray                       # pool slot per token in edge
    children: Dict[int, "RadixNode"]        # first-token -> child
    parent: Optional["RadixNode"]
    refcount: int = 0
    last_used: float = 0.0

    def is_leaf(self) -> bool:
        return not self.children


class RadixTree:
    def __init__(self):
        self.root = RadixNode(tokens=[], slots=np.zeros((0,), np.int32),
                              children={}, parent=None, refcount=1)
        self.hits = 0
        self.misses = 0

    # -- lookup -------------------------------------------------------------
    def match_prefix(self, tokens: List[int]) -> Tuple[np.ndarray, List[RadixNode]]:
        """Longest cached prefix of ``tokens``. Returns (slot indices,
        path nodes whose refcounts the caller now holds)."""
        node = self.root
        matched: List[np.ndarray] = []
        path: List[RadixNode] = []
        i = 0
        while i < len(tokens):
            child = node.children.get(tokens[i])
            if child is None:
                break
            el = len(child.tokens)
            j = 0
            while j < el and i + j < len(tokens) and child.tokens[j] == tokens[i + j]:
                j += 1
            if j == 0:
                break
            if j < el:
                # partial edge match: split is only needed on insert;
                # for lookup just take the matched half.
                matched.append(child.slots[:j])
                child.refcount += 1
                child.last_used = time.monotonic()
                path.append(child)
                i += j
                break
            matched.append(child.slots)
            child.refcount += 1
            child.last_used = time.monotonic()
            path.append(child)
            node = child
            i += el
        if matched:
            self.hits += 1
        else:
            self.misses += 1
        slots = (np.concatenate(matched).astype(np.int32)
                 if matched else np.zeros((0,), np.int32))
        return slots, path

    def release(self, path: List[RadixNode]) -> None:
        for n in path:
            n.refcount -= 1

    # -- insert -------------------------------------------------------------
    def insert(self, tokens: List[int], slots: np.ndarray) -> None:
        """Register a decoded sequence's (tokens -> pool slots) mapping."""
        assert len(tokens) == len(slots)
        node = self.root
        i = 0
        while i < len(tokens):
            child = node.children.get(tokens[i])
            if child is None:
                new = RadixNode(
                    tokens=list(tokens[i:]),
                    slots=np.asarray(slots[i:], np.int32),
                    children={}, parent=node,
                    last_used=time.monotonic(),
                )
                node.children[tokens[i]] = new
                return
            el = len(child.tokens)
            j = 0
            while j < el and i + j < len(tokens) and child.tokens[j] == tokens[i + j]:
                j += 1
            if j == el:
                node = child
                i += el
                continue
            # split the edge at j
            suffix = RadixNode(
                tokens=child.tokens[j:],
                slots=child.slots[j:],
                children=child.children,
                parent=child,
                refcount=child.refcount,
                last_used=child.last_used,
            )
            for gn in suffix.children.values():
                gn.parent = suffix
            child.tokens = child.tokens[:j]
            child.slots = child.slots[:j]
            child.children = {suffix.tokens[0]: suffix}
            node = child
            i += j
        # full match: nothing to add

    def n_cached_tokens(self) -> int:
        total = 0
        stack = [self.root]
        while stack:
            n = stack.pop()
            total += len(n.tokens)
            stack.extend(n.children.values())
        return total
