"""Paged KV pool + host-side page allocator (the MedVerse Engine's
memory system; paper Sec. 4.3, adapted for TPU per DESIGN.md §3).

Device side: one append-only pool per layer, shape
``(L, n_pages * page_size, n_kv, head_dim)``. Streams address tokens by
*index chains* — host-built int32 arrays of flat pool slots. The pool is
append-only: existing slots are never overwritten, so

  * **Fork** = copy the parent's (host) index array and keep appending
    into freshly allocated pages → zero device copies, O(1) device work.
  * **Join** = concatenate predecessor chains (shared prefix counted
    once) → zero device copies.

This is the radix-attention "flexible cache layout" claim realized with
static-shape gathers (TPU-friendly) instead of CUDA pointer chasing.

Host side: a refcounted page allocator. Pages are freed when the last
stream referencing them is released.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.trace import NULL_RECORDER


class OutOfPagesError(RuntimeError):
    pass


@dataclasses.dataclass
class PoolConfig:
    n_layers: int
    n_pages: int
    page_size: int
    n_kv_heads: int
    head_dim: int
    dtype: str = "float32"
    # storage dtype of the K/V pool itself: "f32" stores `dtype`, "int8"
    # stores int8 K/V plus per-page-per-head float32 absmax scales
    kv_dtype: str = "f32"

    @property
    def n_slots(self) -> int:
        return self.n_pages * self.page_size

    @property
    def quantized(self) -> bool:
        return self.kv_dtype == "int8"

    @property
    def kv_itemsize(self) -> int:
        return 1 if self.quantized else jnp.dtype(self.dtype).itemsize

    @property
    def page_bytes(self) -> int:
        """Device bytes one page costs across all layers (K + V, plus the
        per-page-per-head scale rows under int8) — the unit for sizing a
        pool from a byte budget."""
        body = (self.n_layers * 2 * self.page_size * self.n_kv_heads
                * self.head_dim * self.kv_itemsize)
        scales = self.n_layers * 2 * self.n_kv_heads * 4 if self.quantized else 0
        return body + scales


def pages_for_budget(pc: PoolConfig, budget_bytes: int) -> int:
    """How many pages fit in ``budget_bytes`` under ``pc``'s layout.

    The same byte budget buys ~4x the pages under int8 — the capacity
    side of KV quantization (fewer out-of-pages preemptions)."""
    return max(int(budget_bytes) // pc.page_bytes, 1)


def init_pool(pc: PoolConfig) -> Dict[str, jnp.ndarray]:
    shape = (pc.n_layers, pc.n_slots, pc.n_kv_heads, pc.head_dim)
    if pc.quantized:
        pool = {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            # per-(layer, page, kv_head) absmax scales; 0 = empty page
            "k_scale": jnp.zeros((pc.n_layers, pc.n_pages, pc.n_kv_heads),
                                 jnp.float32),
            "v_scale": jnp.zeros((pc.n_layers, pc.n_pages, pc.n_kv_heads),
                                 jnp.float32),
        }
    else:
        dt = jnp.dtype(pc.dtype)
        pool = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    # adaptive position of each stored token (shared across layers)
    pool["pos"] = jnp.zeros((pc.n_slots,), jnp.int32)
    return pool


class PageAllocator:
    """Refcounted free-list allocator over pool pages (host-side).

    Two kinds of references:

      * stream refs (``incref``/``decref``) — held by live index chains;
      * cache pins (``pin``/``unpin``) — held by the radix prefix cache.

    ``used`` counts only pages with at least one stream ref: after a
    request finishes and its chains are released, ``used`` returns to the
    pre-request level even though the radix cache may keep prompt pages
    pinned. Pinned-only pages are reclaimable cache — ``reclaim_cb`` (the
    engine wires it to radix eviction) is invoked when the free list runs
    dry, before giving up with :class:`OutOfPagesError`.
    """

    def __init__(self, pc: PoolConfig, reclaim_cb=None):
        self.pc = pc
        self.free: List[int] = list(range(pc.n_pages))
        self.refs: Dict[int, int] = {}
        self.pinned: Dict[int, int] = {}   # page -> cache pin count
        self.total_allocated = 0           # lifetime alloc_page count
        self.total_freed = 0               # lifetime pages returned free
        self.total_pins = 0                # lifetime cache pins taken
        self.total_unpins = 0              # lifetime cache pins dropped
        self.total_reclaims = 0            # successful reclaim_cb rounds
        self.peak_in_use = 0               # high-water pages_in_use
        self.reclaim_cb = reclaim_cb       # () -> bool (freed something)
        # trace hook: events emitted only when a recorder is attached
        # (the engine sets this when EngineConfig.trace is on)
        self.tracer = NULL_RECORDER

    def alloc_page(self) -> int:
        if not self.free and self.reclaim_cb is not None:
            while not self.free:
                if not self.reclaim_cb():
                    break
                self.total_reclaims += 1
        if not self.free:
            raise OutOfPagesError(
                f"pool exhausted ({self.pc.n_pages} pages)")
        pg = self.free.pop()
        self.refs[pg] = 1
        self.total_allocated += 1
        if self.pages_in_use > self.peak_in_use:
            self.peak_in_use = self.pages_in_use
        if self.tracer.enabled:
            self.tracer.instant("page_alloc", "kvcache", page=pg,
                                in_use=self.pages_in_use)
        return pg

    def incref(self, page: int) -> None:
        self.refs[page] += 1

    def decref(self, page: int) -> None:
        self.refs[page] -= 1
        if self.refs[page] == 0:
            del self.refs[page]
            self.free.append(page)
            self.total_freed += 1
            if self.tracer.enabled:
                self.tracer.instant("page_free", "kvcache", page=page,
                                    in_use=self.pages_in_use)

    # -- cache pins (radix prefix cache) ------------------------------------
    def pin(self, page: int) -> None:
        """Take a *cache* reference on an already-referenced page.

        Invariants: a pin is always added on top of at least one live
        stream ref (the radix tree pins a node's pages at insert time,
        while the inserting chain still holds them), so ``refs[page]``
        exists; a pinned-only page (all stream refs gone) stays out of
        the free list but is excluded from :attr:`used` — it is
        reclaimable cache, freed by ``unpin`` when the radix node is
        evicted (LRU, via ``reclaim_cb`` under page pressure). Each pin
        must be matched by exactly one ``unpin``."""
        self.refs[page] += 1
        self.pinned[page] = self.pinned.get(page, 0) + 1
        self.total_pins += 1
        if self.tracer.enabled:
            self.tracer.instant("page_pin", "kvcache", page=page,
                                pins=self.pinned[page])

    def unpin(self, page: int) -> None:
        self.pinned[page] -= 1
        if self.pinned[page] == 0:
            del self.pinned[page]
        self.total_unpins += 1
        if self.tracer.enabled:
            self.tracer.instant("page_unpin", "kvcache", page=page,
                                pins=self.pinned.get(page, 0))
        self.decref(page)

    @property
    def pages_in_use(self) -> int:
        return self.pc.n_pages - len(self.free)

    @property
    def used(self) -> int:
        """Pages held by live streams (excludes pinned-only cache pages)."""
        return sum(1 for pg, r in self.refs.items()
                   if r > self.pinned.get(pg, 0))

    @property
    def pinned_pages(self) -> int:
        return len(self.pinned)

    def stats(self) -> Dict[str, int]:
        """Lifetime counter set plus current occupancy — the page-pool
        telemetry surface (merged into the engine metrics registry and
        asserted by the no-page-leak tests).

        Invariants a healthy pool satisfies at any quiescent point:
        ``allocs - frees == in_use`` (every allocated page is either
        live or was returned), ``pins - unpins == sum of outstanding
        pin counts``, and ``peak_in_use <= n_pages``."""
        return {
            "allocs": self.total_allocated,
            "frees": self.total_freed,
            "pins": self.total_pins,
            "unpins": self.total_unpins,
            "reclaims": self.total_reclaims,
            "peak_in_use": self.peak_in_use,
            "in_use": self.pages_in_use,
            "used": self.used,
            "pinned": self.pinned_pages,
            "n_pages": self.pc.n_pages,
        }


class IndexChain:
    """A stream's view of the pool: flat token slot indices, append-only.

    ``pages``: the pages this chain references (for refcounting).
    ``write_page``/``write_off``: current append cursor (owned page).
    """

    __slots__ = ("alloc", "idx", "length", "pages", "own_pages",
                 "write_page", "write_off")

    def __init__(self, alloc: PageAllocator):
        self.alloc = alloc
        self.idx = np.zeros((0,), np.int32)
        self.length = 0
        self.pages: Set[int] = set()
        # pages *allocated by this chain's own appends* (never inherited
        # via fork/join/adopt) — the only pages pop_slot may empty and
        # the only pages the write cursor may re-enter on rollback,
        # preserving the single-writer-per-page invariant
        self.own_pages: Set[int] = set()
        self.write_page: Optional[int] = None
        self.write_off = 0

    # -- construction -----------------------------------------------------
    @staticmethod
    def fresh(alloc: PageAllocator) -> "IndexChain":
        return IndexChain(alloc)

    def fork(self) -> "IndexChain":
        """Zero-copy fork: child references the same tokens (read-only) and
        appends into its own pages."""
        child = IndexChain(self.alloc)
        child.idx = self.idx[: self.length].copy()  # host ints only
        child.length = self.length
        child.pages = set(self.pages)
        for pg in child.pages:
            self.alloc.incref(pg)
        # child gets its own write page lazily on first append
        return child

    @staticmethod
    def join(chains: List["IndexChain"], prefix_len: int) -> "IndexChain":
        """Merge predecessor chains that share a common prefix of
        ``prefix_len`` tokens: prefix once, then each branch's suffix in
        order. Zero device copies."""
        assert chains
        alloc = chains[0].alloc
        out = IndexChain(alloc)
        parts = [chains[0].idx[:prefix_len]]
        pages: Set[int] = set()
        for ch in chains:
            parts.append(ch.idx[prefix_len:ch.length])
            pages |= ch.pages
        out.idx = np.concatenate(parts).astype(np.int32)
        out.length = int(out.idx.shape[0])
        out.pages = pages
        for pg in pages:
            alloc.incref(pg)
        return out

    def adopt(self, slots: np.ndarray) -> None:
        """Reference existing pool slots (a radix prefix hit) without
        owning them: increfs their pages once each; subsequent appends go
        into this chain's own freshly allocated pages."""
        slots = np.asarray(slots, np.int32)
        if slots.size == 0:
            return
        pg_size = self.alloc.pc.page_size
        self.idx = np.concatenate([self.idx[: self.length], slots])
        self.length = int(self.idx.shape[0])
        for pg in {int(s) // pg_size for s in slots}:
            if pg not in self.pages:
                self.alloc.incref(pg)
                self.pages.add(pg)

    def release(self) -> None:
        for pg in self.pages:
            self.alloc.decref(pg)
        self.pages.clear()
        self.own_pages.clear()
        self.length = 0
        self.idx = np.zeros((0,), np.int32)
        self.write_page = None

    # -- appending ---------------------------------------------------------
    def next_slot(self) -> int:
        """Reserve the next pool slot for this stream's new token."""
        pg_size = self.alloc.pc.page_size
        if self.write_page is None or self.write_off == pg_size:
            self.write_page = self.alloc.alloc_page()
            self.pages.add(self.write_page)
            self.own_pages.add(self.write_page)
            self.write_off = 0
        slot = self.write_page * pg_size + self.write_off
        self.write_off += 1
        self.idx = np.append(self.idx, np.int32(slot))
        self.length += 1
        return slot

    def pop_slot(self) -> None:
        """Undo the most recent ``next_slot``.

        Used two ways: a batched step reserves its slots before
        committing any tokens and unwinds all of them if the pool runs
        dry mid-batch (preemption rollback), and speculative decoding
        unwinds a block's rejected draft rows the same way. Within a
        page the write page stays owned by the chain — the popped slot
        is simply handed out again on the next append. When a multi-row
        rollback empties a page, that page was necessarily allocated by
        this chain's own appends (inherited pages hold only committed
        prefix slots, which are never popped), so it is returned to the
        allocator and the cursor re-derived from the chain tail — a
        fully rejected draft leaves page accounting exactly where it
        started."""
        assert self.length > 0 and self.write_off > 0, "nothing to pop"
        self.write_off -= 1
        self.idx = self.idx[:-1]
        self.length -= 1
        if self.write_off > 0:
            return
        pg = self.write_page
        self.pages.discard(pg)
        self.own_pages.discard(pg)
        self.alloc.decref(pg)
        pg_size = self.alloc.pc.page_size
        if self.length > 0:
            last_pg = int(self.idx[-1]) // pg_size
            if last_pg in self.own_pages:
                # cursor returns to the previous own page (full or not:
                # off == page_size just means the next append allocates)
                self.write_page = last_pg
                self.write_off = int(self.idx[-1]) % pg_size + 1
                return
        # tail is inherited (or the chain is empty): back to the
        # lazy-allocation state; the next append gets a fresh page
        self.write_page = None
        self.write_off = 0

    def reserve(self, n: int) -> np.ndarray:
        return np.asarray([self.next_slot() for _ in range(n)], np.int32)

    def padded(self, max_len: int) -> np.ndarray:
        out = np.zeros((max_len,), np.int32)
        out[: self.length] = self.idx[: self.length]
        return out

    def page_runs(self) -> Tuple[np.ndarray, np.ndarray]:
        """``(pages, valid)``: the chain's pages in first-appearance
        order and how many slots of each the chain references.

        This is the chain expressed in the Pallas decode kernel's native
        page-table structure. It relies on an invariant the pool
        maintains by construction: every page a chain references is
        referenced on a *contiguous prefix* of that page's slots. Pages
        are single-writer (``next_slot`` fills the owned write page
        sequentially; forks and radix adoptions never append into an
        inherited page) and every inheritance path — fork, ordered-dedup
        join, radix prefix adoption — truncates or copies a sequential
        run, so per-page references stay ``{0 .. count-1}``. Attention
        over ``valid[i]`` leading slots of each page therefore covers
        exactly the chain's slot set.
        """
        if self.length == 0:
            return (np.zeros((0,), np.int32), np.zeros((0,), np.int32))
        pg = self.idx[: self.length] // self.alloc.pc.page_size
        uniq, first, counts = np.unique(pg, return_index=True,
                                        return_counts=True)
        order = np.argsort(first, kind="stable")
        return uniq[order].astype(np.int32), counts[order].astype(np.int32)


# ----------------------------------------------------- device pool writes --
@jax.jit
def pool_write(pool_k, pool_v, pool_pos, layer_kv_k, layer_kv_v,
               slots, positions):
    """Write one token per stream into the pool.

    layer_kv_k/v: (L, n_streams, n_kv, hd); slots: (n_streams,) flat slot
    ids; positions: (n_streams,) adaptive positions.
    """
    pool_k = pool_k.at[:, slots].set(layer_kv_k)
    pool_v = pool_v.at[:, slots].set(layer_kv_v)
    pool_pos = pool_pos.at[slots].set(positions)
    return pool_k, pool_v, pool_pos


@jax.jit
def pool_write_span(pool_k, pool_v, pool_pos, kv_k, kv_v, slots, positions):
    """Write a span of tokens (prefill). kv_k/v: (L, S, n_kv, hd);
    slots: (S,); positions: (S,)."""
    pool_k = pool_k.at[:, slots].set(kv_k)
    pool_v = pool_v.at[:, slots].set(kv_v)
    pool_pos = pool_pos.at[slots].set(positions)
    return pool_k, pool_v, pool_pos


# -- int8 quantized writes ------------------------------------------------
#
# Pages quantize per (layer, page, kv_head) with a float32 absmax scale:
# stored = round(x / scale), scale = absmax/127, dequant = int8 * scale.
# Pages fill append-only from in-page offset 0 (adopt/fork never re-enter
# an inherited page), so a write at offset 0 is always the first token of
# a freshly (re)allocated page: it RESETS the scale and zeroes the stale
# page body. Later writes into the page may only GROW the scale; when it
# grows, the already-stored int8 rows are requantized in place
# (round(old * s_old/s_new)) — a bounded, deterministic precision loss
# covered by the temp-0 parity contract in tests/test_kv_quant.py.
#
# Writes are sequential over rows (fori_loop), never a batched scatter:
# two rows of one speculative block (or one prefill chunk) can land in
# the same page, and each write can bump that page's scale — a duplicate
# scatter index would silently drop the earlier row's rescale.

def _quant_put(pool_l, scale_l, row, slot, page_size):
    """Write one (n_kv, hd) float32 row into a single layer's int8 pool at
    ``slot`` (sentinel ``>= n_slots`` drops the write)."""
    n_slots = pool_l.shape[0]
    ok = slot < n_slots
    slot_c = jnp.minimum(slot, n_slots - 1)
    page = slot_c // page_size
    pstart = page * page_size
    first = (slot_c - pstart) == 0
    amax = jnp.max(jnp.abs(row), axis=-1)                    # (n_kv,)
    s_old = scale_l[page]                                    # (n_kv,)
    s_new = jnp.where(first, amax / 127.0,
                      jnp.maximum(s_old, amax / 127.0))
    denom = jnp.maximum(s_new, 1e-30)
    # requant factor for rows already in the page; 0 wipes a fresh page
    factor = jnp.where(first, 0.0,
                       jnp.where(s_new > 0, s_old / denom, 1.0))
    pg = jax.lax.dynamic_slice_in_dim(pool_l, pstart, page_size)
    pg2 = jnp.clip(jnp.round(pg.astype(jnp.float32) * factor[None, :, None]),
                   -127, 127).astype(jnp.int8)
    q = jnp.clip(jnp.round(row / denom[:, None]), -127, 127).astype(jnp.int8)
    pg2 = jax.lax.dynamic_update_slice_in_dim(
        pg2, q[None], slot_c - pstart, axis=0)
    pool_l = jax.lax.dynamic_update_slice_in_dim(
        pool_l, jnp.where(ok, pg2, pg), pstart, axis=0)
    scale_l = scale_l.at[page].set(jnp.where(ok, s_new, s_old))
    return pool_l, scale_l


def quant_write_rows(pool_l, scale_l, rows, slots, page_size):
    """Quantize-write one token per batch row into one layer's int8 pool.

    pool_l: (n_slots, n_kv, hd) int8; scale_l: (n_pages, n_kv) f32;
    rows: (N, n_kv, hd) f32; slots: (N,) int32 (``n_slots`` = drop).
    Traced inline by ``paged_decode`` — not independently jitted."""
    def body(i, carry):
        p, s = carry
        return _quant_put(p, s, rows[i], slots[i], page_size)
    return jax.lax.fori_loop(0, rows.shape[0], body, (pool_l, scale_l))


def quant_write_span(pool_k, pool_v, k_scale, v_scale, kv_k, kv_v, slots,
                     page_size):
    """Quantize-write a prefill span across all layers.

    pool_k/v: (L, n_slots, n_kv, hd) int8; k/v_scale: (L, n_pages, n_kv);
    kv_k/v: (L, S, n_kv, hd) f32; slots: (S,) (``n_slots`` = drop)."""
    n_layers = pool_k.shape[0]

    def body(i, carry):
        pk, pv, ks, vs = carry
        slot = slots[i]

        def per_layer(li, c):
            pk_, pv_, ks_, vs_ = c
            pkl, ksl = _quant_put(pk_[li], ks_[li], kv_k[li, i], slot,
                                  page_size)
            pvl, vsl = _quant_put(pv_[li], vs_[li], kv_v[li, i], slot,
                                  page_size)
            return (pk_.at[li].set(pkl), pv_.at[li].set(pvl),
                    ks_.at[li].set(ksl), vs_.at[li].set(vsl))

        return jax.lax.fori_loop(0, n_layers, per_layer, (pk, pv, ks, vs))

    return jax.lax.fori_loop(0, slots.shape[0], body,
                             (pool_k, pool_v, k_scale, v_scale))
