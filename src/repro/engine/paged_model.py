"""Model execution against the paged pool: chunked prefill (collecting
post-RoPE K/V into pool pages) and the batched paged decode step that
the continuous batcher calls once per engine iteration.

Engine-supported layer kinds: ATTN and LOCAL_ATTN (the paper's engine
targets decoder LLMs; MoE FFNs work; MLA/SSM decode goes through the
dense ``models.decode_step`` path — see DESIGN.md §4).

Attention backends (``EngineConfig.attention_backend``):

* ``"dense"`` — gather the chain's K/V out of the pool and run a masked
  jnp SDPA. Reference semantics; what every XLA backend supports.
* ``"pallas"`` — the hot path. Decode goes through the paged GQA flash
  kernel (``kernels.decode_attention``): the page table built from each
  stream's index chain is scalar-prefetched and the kernel streams
  exactly the chain's live pages, no gather materialization. Prefill
  goes through the chunked DAG flash kernel (``kernels.dag_attention``)
  in its degenerate linear topology. Both kernels accumulate the softmax
  in float32 exactly like ``_sdpa``; outputs agree to float32 rounding
  (~1e-6 relative — flash renormalization reorders the reduction), which
  is atol-bounded, not bit-identical. Temp-0 decoding is stable against
  that at the argmax, and every scheduling path (sync/async frontier,
  radix hits, preemption/re-prefill) is backend-agnostic host logic.
  ``attn_logit_softcap`` is not implemented in the kernels and is
  rejected at engine construction.

All functions are functional: the pool arrays flow in and out of jitted
steps; index chains and positions are built host-side (scheduling is
<0.01% of wall-clock — paper Table 2 — and ours is too, see
benchmarks/table2_cost_decomp.py).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.masks import NEG_INF
from ..kernels.dag_attention.ops import causal_prefill_attention
from ..kernels.decode_attention.ops import paged_decode_attention_flat
from .kvcache import quant_write_rows, quant_write_span
from ..models.attention import TopoBatch
from ..models.config import ATTN, LOCAL_ATTN, ModelConfig
from ..models.layers import apply_mlp, apply_norm, apply_rope, embed_tokens, unembed
from ..models.moe import moe_ffn
from ..models.transformer import compute_stages

ATTENTION_BACKENDS = ("dense", "pallas")


def check_backend(cfg: ModelConfig, backend: str) -> None:
    """Validate an attention-backend choice against the model config."""
    if backend not in ATTENTION_BACKENDS:
        raise ValueError(
            f"attention_backend={backend!r}: expected one of "
            f"{ATTENTION_BACKENDS}")
    if backend == "pallas" and cfg.attn_logit_softcap > 0:
        raise NotImplementedError(
            f"{cfg.name}: attn_logit_softcap={cfg.attn_logit_softcap} is "
            "not implemented in the Pallas attention kernels; use "
            "attention_backend='dense'")


def _layer_list(cfg: ModelConfig):
    """Flatten stage params into a per-layer list at engine init."""
    stages = compute_stages(cfg)
    out = []
    for st in stages:
        for n in range(st.n):
            for i, kind in enumerate(st.unit):
                out.append((st, n, i, kind))
    return out


def flatten_params(params: dict, cfg: ModelConfig) -> List[dict]:
    layers = []
    for si, st in enumerate(compute_stages(cfg)):
        sp = params["stages"][si]
        for n in range(st.n):
            for i, kind in enumerate(st.unit):
                lp = jax.tree_util.tree_map(lambda a, n=n: a[n], sp[f"u{i}"])
                layers.append({"params": lp, "kind": kind, "moe": st.moe})
    return layers


def _proj_qkv(p, h, cfg, pos):
    b, s, _ = h.shape
    hd, nh, nkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = (h @ p["wq"]).reshape(b, s, nh, hd)
    k = (h @ p["wk"]).reshape(b, s, nkv, hd)
    v = (h @ p["wv"]).reshape(b, s, nkv, hd)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm", cfg.norm_eps)
        k = apply_norm(p["k_norm"], k, "rmsnorm", cfg.norm_eps)
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, bias, softcap=0.0):
    """q:(B,Sq,nh,hd) k,v:(B,Sk,nkv,hd) bias broadcastable to
    (B,1,1,Sq,Sk). Returns (B,Sq,nh*hd) f32->x dtype."""
    b, sq, nh, hd = q.shape
    nkv = k.shape[2]
    g = nh // nkv
    qg = q.reshape(b, sq, nkv, g, hd)
    sc = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                    k.astype(jnp.float32)) / math.sqrt(hd)
    if softcap > 0:
        sc = jnp.tanh(sc / softcap) * softcap
    sc = sc + bias
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.reshape(b, sq, nh * hd)


def decode_attention_dense(q, k_slots, v_slots, pool_pos, chain_idx,
                           chain_len, q_pos, *, window: int = 0,
                           softcap: float = 0.0, k_scale=None, v_scale=None,
                           page_size: int = 0):
    """Per-layer decode attention of the ``"dense"`` backend: gather each
    stream's index chain out of the flat slot pool and run the masked
    SDPA. Visibility is the length mask composed with the adaptive-
    position mask ``kv_pos <= q_pos`` (join-max semantics) and, when
    ``window`` is set, the sliding window on stored positions.

    q: (N, 1, NH, HD); k_slots/v_slots: (n_slots, NKV, HD) — one layer
    of the pool; chain_idx: (N, S_max); returns (N, 1, NH*HD) float32.
    With an int8 pool, ``k_scale``/``v_scale`` are the layer's
    (n_pages, NKV) absmax scales and the gather dequantizes in float32
    (``int8 * scale[slot // page_size]``) before the SDPA.
    This is also the reference tier ``benchmarks/kernel_bench.py`` times
    the paged schedule against — keep it the shipped dense path.
    """
    s_max = chain_idx.shape[1]
    valid = jnp.arange(s_max)[None, :] < chain_len[:, None]  # (N, S_max)
    kv_pos = pool_pos[chain_idx]                             # (N, S_max)
    vis = valid & (kv_pos <= q_pos[:, None])
    if window:
        diff = q_pos[:, None] - kv_pos
        vis = vis & (diff >= 0) & (diff < window)
    bias = jnp.where(vis, 0.0, NEG_INF)[:, None, None, None, :]
    k = k_slots[chain_idx]
    v = v_slots[chain_idx]
    if k_scale is not None:
        pages = chain_idx // page_size                       # (N, S_max)
        k = k.astype(jnp.float32) * k_scale[pages][..., None]
        v = v.astype(jnp.float32) * v_scale[pages][..., None]
    return _sdpa(q, k, v, bias, softcap)


# ------------------------------------------------------------- prefill -----
@partial(jax.jit, static_argnames=("cfg", "backend", "interpret"))
def prefill_forward(params: dict, tokens: jnp.ndarray, pos: jnp.ndarray,
                    cfg: ModelConfig, true_len: jnp.ndarray = None,
                    *, backend: str = "dense", interpret: bool = True):
    """Linear (causal) prefill of (1, S) tokens (S may be padded to a
    bucket size — the engine buckets prompt lengths so one compilation
    serves many prompts). ``backend="pallas"`` runs each layer's
    attention through the chunked DAG flash kernel (linear topology)
    instead of the dense masked SDPA. Returns (logits at true_len-1
    (V,), kvs {k,v}: (L, S, nkv, hd) post-RoPE)."""
    check_backend(cfg, backend)  # trace-time: softcap is dense-only
    b, s = tokens.shape
    if true_len is None:
        true_len = jnp.int32(s)
    x = embed_tokens(params["embed"], tokens)
    if cfg.pos_embedding == "learned":
        from ..models.layers import learned_pos
        x = x + learned_pos(params["pos"], pos)
    idx = jnp.arange(s)
    causal = idx[None, :] <= idx[:, None]
    bias = jnp.where(causal, 0.0, NEG_INF)[None, None, None]
    ks, vs = [], []
    for layer in flatten_params(params, cfg):
        p, kind = layer["params"], layer["kind"]
        h = apply_norm(p["norm1"], x, cfg.norm_type, cfg.norm_eps)
        q, k, v = _proj_qkv(p["mixer"], h, cfg, pos)
        win = cfg.sliding_window if kind == LOCAL_ATTN else 0
        if backend == "pallas":
            # positions are the engine's adaptive positions: inside one
            # linear prefill they are the packed order, so the kernel's
            # causal mask matches the dense path and the window composes
            # on positions exactly as below
            att = causal_prefill_attention(
                q, k, v, pos, window=win,
                interpret=interpret).reshape(b, s, -1)
        else:
            lbias = bias
            if kind == LOCAL_ATTN:
                diff = pos[:, :, None] - pos[:, None, :]
                winm = (diff >= 0) & (diff < win)
                lbias = bias + jnp.where(winm, 0.0, NEG_INF)[:, None, None]
            att = _sdpa(q, k, v, lbias, cfg.attn_logit_softcap)
        att = att.astype(x.dtype) @ p["mixer"]["wo"]
        x = x + att
        h2 = apply_norm(p["norm2"], x, cfg.norm_type, cfg.norm_eps)
        if layer["moe"]:
            y, _ = moe_ffn(p["ffn"], h2, cfg)
        else:
            y = apply_mlp(p["ffn"], h2, cfg.mlp_activation)
        x = x + y
        ks.append(k[0])
        vs.append(v[0])
    x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    head = params["lm_head"] if "lm_head" in params else params["embed"]["table"].T
    x_last = jax.lax.dynamic_slice_in_dim(x, true_len - 1, 1, axis=1)
    logits = unembed(head, x_last[:, 0], cfg.logit_softcap)[0]
    return logits, jnp.stack(ks), jnp.stack(vs)


@partial(jax.jit, donate_argnums=(0, 1, 2))
def prefix_pool_write(pool_k, pool_v, pool_pos, ks, vs, slots, pos):
    """Write a prefill K/V span into the pool with per-row drop support.

    ks/vs: (L, B, nkv, hd) from ``prefill_forward`` (B = prefill bucket);
    slots/pos: (B,). Rows whose slot is out of range (the engine uses
    ``n_slots`` as the sentinel) are dropped — that covers both bucket
    padding and radix-cached prefix positions, whose slots already hold
    identical K/V. One compiled shape serves every prompt in a bucket
    regardless of how much prefix the radix cache supplied.
    """
    pool_k = pool_k.at[:, slots].set(ks.astype(pool_k.dtype), mode="drop")
    pool_v = pool_v.at[:, slots].set(vs.astype(pool_v.dtype), mode="drop")
    pool_pos = pool_pos.at[slots].set(pos, mode="drop")
    return pool_k, pool_v, pool_pos


@partial(jax.jit, static_argnames=("page_size",),
         donate_argnums=(0, 1, 2, 3, 4))
def prefix_pool_write_quant(pool_k, pool_v, pool_pos, k_scale, v_scale,
                            ks, vs, slots, pos, *, page_size: int):
    """Int8 variant of :func:`prefix_pool_write`: quantize the prefill
    span page by page (absmax scales, see ``kvcache.quant_write_span``)
    with the same sentinel-slot drop semantics."""
    pool_k, pool_v, k_scale, v_scale = quant_write_span(
        pool_k, pool_v, k_scale, v_scale, ks, vs, slots, page_size)
    pool_pos = pool_pos.at[slots].set(pos, mode="drop")
    return pool_k, pool_v, pool_pos, k_scale, v_scale


# -------------------------------------------------------------- decode -----
@partial(jax.jit,
         static_argnames=("cfg", "backend", "page_size", "interpret"),
         donate_argnums=(1, 2, 3, 4, 5))
def paged_decode(params: dict,
                 pool_k: jnp.ndarray,     # (L, n_slots, nkv, hd)
                 pool_v: jnp.ndarray,
                 pool_pos: jnp.ndarray,   # (n_slots,)
                 k_scale,                 # (L, n_pages, nkv) f32 | None
                 v_scale,                 # int8 pool absmax scales
                 token_ids: jnp.ndarray,  # (N,)
                 q_pos: jnp.ndarray,      # (N,)
                 write_slots: jnp.ndarray,  # (N,) flat pool slot per stream
                 chain_idx: jnp.ndarray,  # (N, S_max) flat slot chains
                 chain_len: jnp.ndarray,  # (N,) incl. the new token
                 cfg: ModelConfig, *,
                 backend: str = "dense",
                 page_table: jnp.ndarray = None,  # (N, P_max) chain pages
                 page_valid: jnp.ndarray = None,  # (N, P_max) slots per page
                 page_size: int = 0,
                 interpret: bool = True):
    """One decode step for all active streams against their index chains.

    Visibility needs no DAG mask here: a chain *is* the stream's ancestor
    history by construction (Petri-net token semantics) — only the length
    mask, the adaptive-position mask ``kv_pos <= q_pos`` (join-max
    semantics), and the sliding window on LOCAL_ATTN layers apply. One
    transformer body serves both backends; only the per-layer attention
    call dispatches on the static ``backend``:

    * ``"dense"`` — gather each chain (``chain_idx``/``chain_len``) out
      of the flat pool and run the masked SDPA
      (:func:`decode_attention_dense`).
    * ``"pallas"`` — the paged flash kernel. The ancestor set is
      expressed as ``(page_table, page_valid)`` rows built host-side
      from the chains (``IndexChain.page_runs``): the kernel
      scalar-prefetches the table and streams exactly the chain's pages,
      no gather materialization. Padding rows carry ``page_valid == 0``
      (every page skipped).

    Batch padding rows carry an out-of-range write slot (the ``n_slots``
    sentinel) and must not scatter into the pool (``mode="drop"``).

    With an int8 pool (``k_scale``/``v_scale`` not None) each layer's new
    K/V rows are quantize-written sequentially (two block rows can share
    a page and bump its scale — see ``kvcache.quant_write_rows``) and
    both backends dequantize on read; the f32 path passes ``None`` and is
    byte-identical to before.
    """
    check_backend(cfg, backend)  # trace-time: softcap is dense-only
    n = token_ids.shape[0]
    quantized = k_scale is not None
    x = embed_tokens(params["embed"], token_ids)[:, None, :]
    if cfg.pos_embedding == "learned":
        from ..models.layers import learned_pos
        x = x + learned_pos(params["pos"], q_pos)[:, None, :]
    pool_pos = pool_pos.at[write_slots].set(q_pos, mode="drop")
    for li, layer in enumerate(flatten_params(params, cfg)):
        p, kind = layer["params"], layer["kind"]
        h = apply_norm(p["norm1"], x, cfg.norm_type, cfg.norm_eps)
        q, k_t, v_t = _proj_qkv(p["mixer"], h, cfg, q_pos[:, None])
        if quantized:
            pk_l, ks_l = quant_write_rows(
                pool_k[li], k_scale[li], k_t[:, 0].astype(jnp.float32),
                write_slots, page_size)
            pv_l, vs_l = quant_write_rows(
                pool_v[li], v_scale[li], v_t[:, 0].astype(jnp.float32),
                write_slots, page_size)
            pool_k = pool_k.at[li].set(pk_l)
            pool_v = pool_v.at[li].set(pv_l)
            k_scale = k_scale.at[li].set(ks_l)
            v_scale = v_scale.at[li].set(vs_l)
        else:
            pool_k = pool_k.at[li, write_slots].set(
                k_t[:, 0].astype(pool_k.dtype), mode="drop")
            pool_v = pool_v.at[li, write_slots].set(
                v_t[:, 0].astype(pool_v.dtype), mode="drop")
        win = cfg.sliding_window if kind == LOCAL_ATTN else 0
        if backend == "pallas":
            att = paged_decode_attention_flat(
                q[:, 0], pool_k[li], pool_v[li], pool_pos,
                page_table, page_valid, q_pos,
                page_size=page_size, window=win,
                k_scale=k_scale[li] if quantized else None,
                v_scale=v_scale[li] if quantized else None,
                interpret=interpret).reshape(n, 1, -1)
        else:
            att = decode_attention_dense(
                q, pool_k[li], pool_v[li], pool_pos, chain_idx, chain_len,
                q_pos, window=win, softcap=cfg.attn_logit_softcap,
                k_scale=k_scale[li] if quantized else None,
                v_scale=v_scale[li] if quantized else None,
                page_size=page_size)
        x = x + att.astype(x.dtype) @ p["mixer"]["wo"]
        h2 = apply_norm(p["norm2"], x, cfg.norm_type, cfg.norm_eps)
        if layer["moe"]:
            y, _ = moe_ffn(p["ffn"], h2, cfg)
        else:
            y = apply_mlp(p["ffn"], h2, cfg.mlp_activation)
        x = x + y
    x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    head = params["lm_head"] if "lm_head" in params else params["embed"]["table"].T
    logits = unembed(head, x[:, 0], cfg.logit_softcap)       # (N, V)
    return logits, pool_k, pool_v, pool_pos, k_scale, v_scale


def supports_paged(cfg: ModelConfig) -> bool:
    return (cfg.mla is None and cfg.encoder is None
            and all(k in (ATTN, LOCAL_ATTN) for k in cfg.layer_kinds))
