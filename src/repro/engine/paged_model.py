"""Model execution against the paged pool: chunked prefill (collecting
post-RoPE K/V into pool pages) and the batched paged decode step that
the continuous batcher calls once per engine iteration.

Engine-supported layer kinds: ATTN and LOCAL_ATTN (the paper's engine
targets decoder LLMs; MoE FFNs work; MLA/SSM decode goes through the
dense ``models.decode_step`` path — see DESIGN.md §4).

All functions are functional: the pool arrays flow in and out of jitted
steps; index chains and positions are built host-side (scheduling is
<0.01% of wall-clock — paper Table 2 — and ours is too, see
benchmarks/table2_cost_decomp.py).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.masks import NEG_INF
from ..models.attention import TopoBatch
from ..models.config import ATTN, LOCAL_ATTN, ModelConfig
from ..models.layers import apply_mlp, apply_norm, apply_rope, embed_tokens, unembed
from ..models.moe import moe_ffn
from ..models.transformer import compute_stages


def _layer_list(cfg: ModelConfig):
    """Flatten stage params into a per-layer list at engine init."""
    stages = compute_stages(cfg)
    out = []
    for st in stages:
        for n in range(st.n):
            for i, kind in enumerate(st.unit):
                out.append((st, n, i, kind))
    return out


def flatten_params(params: dict, cfg: ModelConfig) -> List[dict]:
    layers = []
    for si, st in enumerate(compute_stages(cfg)):
        sp = params["stages"][si]
        for n in range(st.n):
            for i, kind in enumerate(st.unit):
                lp = jax.tree_util.tree_map(lambda a, n=n: a[n], sp[f"u{i}"])
                layers.append({"params": lp, "kind": kind, "moe": st.moe})
    return layers


def _proj_qkv(p, h, cfg, pos):
    b, s, _ = h.shape
    hd, nh, nkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = (h @ p["wq"]).reshape(b, s, nh, hd)
    k = (h @ p["wk"]).reshape(b, s, nkv, hd)
    v = (h @ p["wv"]).reshape(b, s, nkv, hd)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm", cfg.norm_eps)
        k = apply_norm(p["k_norm"], k, "rmsnorm", cfg.norm_eps)
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, bias, cfg):
    """q:(B,Sq,nh,hd) k,v:(B,Sk,nkv,hd) bias broadcastable to
    (B,1,1,Sq,Sk). Returns (B,Sq,nh*hd) f32->x dtype."""
    b, sq, nh, hd = q.shape
    nkv = k.shape[2]
    g = nh // nkv
    qg = q.reshape(b, sq, nkv, g, hd)
    sc = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                    k.astype(jnp.float32)) / math.sqrt(hd)
    if cfg.attn_logit_softcap > 0:
        sc = jnp.tanh(sc / cfg.attn_logit_softcap) * cfg.attn_logit_softcap
    sc = sc + bias
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.reshape(b, sq, nh * hd)


# ------------------------------------------------------------- prefill -----
@partial(jax.jit, static_argnames=("cfg",))
def prefill_forward(params: dict, tokens: jnp.ndarray, pos: jnp.ndarray,
                    cfg: ModelConfig, true_len: jnp.ndarray = None):
    """Linear (causal) prefill of (1, S) tokens (S may be padded to a
    bucket size — the engine buckets prompt lengths so one compilation
    serves many prompts). Returns (logits at true_len-1 (V,),
    kvs {k,v}: (L, S, nkv, hd) post-RoPE)."""
    b, s = tokens.shape
    if true_len is None:
        true_len = jnp.int32(s)
    x = embed_tokens(params["embed"], tokens)
    if cfg.pos_embedding == "learned":
        from ..models.layers import learned_pos
        x = x + learned_pos(params["pos"], pos)
    idx = jnp.arange(s)
    causal = idx[None, :] <= idx[:, None]
    bias = jnp.where(causal, 0.0, NEG_INF)[None, None, None]
    ks, vs = [], []
    for layer in flatten_params(params, cfg):
        p, kind = layer["params"], layer["kind"]
        h = apply_norm(p["norm1"], x, cfg.norm_type, cfg.norm_eps)
        q, k, v = _proj_qkv(p["mixer"], h, cfg, pos)
        lbias = bias
        if kind == LOCAL_ATTN:
            diff = pos[:, :, None] - pos[:, None, :]
            win = (diff >= 0) & (diff < cfg.sliding_window)
            lbias = bias + jnp.where(win, 0.0, NEG_INF)[:, None, None]
        att = _sdpa(q, k, v, lbias, cfg).astype(x.dtype) @ p["mixer"]["wo"]
        x = x + att
        h2 = apply_norm(p["norm2"], x, cfg.norm_type, cfg.norm_eps)
        if layer["moe"]:
            y, _ = moe_ffn(p["ffn"], h2, cfg)
        else:
            y = apply_mlp(p["ffn"], h2, cfg.mlp_activation)
        x = x + y
        ks.append(k[0])
        vs.append(v[0])
    x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    head = params["lm_head"] if "lm_head" in params else params["embed"]["table"].T
    x_last = jax.lax.dynamic_slice_in_dim(x, true_len - 1, 1, axis=1)
    logits = unembed(head, x_last[:, 0], cfg.logit_softcap)[0]
    return logits, jnp.stack(ks), jnp.stack(vs)


@partial(jax.jit, donate_argnums=(0, 1, 2))
def prefix_pool_write(pool_k, pool_v, pool_pos, ks, vs, slots, pos):
    """Write a prefill K/V span into the pool with per-row drop support.

    ks/vs: (L, B, nkv, hd) from ``prefill_forward`` (B = prefill bucket);
    slots/pos: (B,). Rows whose slot is out of range (the engine uses
    ``n_slots`` as the sentinel) are dropped — that covers both bucket
    padding and radix-cached prefix positions, whose slots already hold
    identical K/V. One compiled shape serves every prompt in a bucket
    regardless of how much prefix the radix cache supplied.
    """
    pool_k = pool_k.at[:, slots].set(ks.astype(pool_k.dtype), mode="drop")
    pool_v = pool_v.at[:, slots].set(vs.astype(pool_v.dtype), mode="drop")
    pool_pos = pool_pos.at[slots].set(pos, mode="drop")
    return pool_k, pool_v, pool_pos


# -------------------------------------------------------------- decode -----
@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1, 2, 3))
def paged_decode(params: dict,
                 pool_k: jnp.ndarray,     # (L, n_slots, nkv, hd)
                 pool_v: jnp.ndarray,
                 pool_pos: jnp.ndarray,   # (n_slots,)
                 token_ids: jnp.ndarray,  # (N,)
                 q_pos: jnp.ndarray,      # (N,)
                 write_slots: jnp.ndarray,  # (N,) flat pool slot per stream
                 chain_idx: jnp.ndarray,  # (N, S_max) flat slot chains
                 chain_len: jnp.ndarray,  # (N,) incl. the new token
                 cfg: ModelConfig):
    """One decode step for all active streams against their index chains.

    Visibility needs no DAG mask here: a chain *is* the stream's ancestor
    history by construction (Petri-net token semantics) — only the length
    mask (and sliding window, from stored positions) applies.
    """
    n, s_max = chain_idx.shape
    x = embed_tokens(params["embed"], token_ids)[:, None, :]
    if cfg.pos_embedding == "learned":
        from ..models.layers import learned_pos
        x = x + learned_pos(params["pos"], q_pos)[:, None, :]
    # padding rows carry an out-of-range write slot (n_slots sentinel)
    # and must not scatter into the pool
    pool_pos = pool_pos.at[write_slots].set(q_pos, mode="drop")
    valid = jnp.arange(s_max)[None, :] < chain_len[:, None]   # (N, S_max)
    kv_pos = pool_pos[chain_idx]                              # (N, S_max)
    for li, layer in enumerate(flatten_params(params, cfg)):
        p, kind = layer["params"], layer["kind"]
        h = apply_norm(p["norm1"], x, cfg.norm_type, cfg.norm_eps)
        q, k_t, v_t = _proj_qkv(p["mixer"], h, cfg, q_pos[:, None])
        pool_k = pool_k.at[li, write_slots].set(
            k_t[:, 0].astype(pool_k.dtype), mode="drop")
        pool_v = pool_v.at[li, write_slots].set(
            v_t[:, 0].astype(pool_v.dtype), mode="drop")
        k = pool_k[li][chain_idx]                             # (N,S,nkv,hd)
        v = pool_v[li][chain_idx]
        vis = valid & (kv_pos <= q_pos[:, None])
        if kind == LOCAL_ATTN:
            diff = q_pos[:, None] - kv_pos
            vis = vis & (diff >= 0) & (diff < cfg.sliding_window)
        bias = jnp.where(vis, 0.0, NEG_INF)[:, None, None, None, :]
        att = _sdpa(q, k, v, bias, cfg).astype(x.dtype) @ p["mixer"]["wo"]
        x = x + att
        h2 = apply_norm(p["norm2"], x, cfg.norm_type, cfg.norm_eps)
        if layer["moe"]:
            y, _ = moe_ffn(p["ffn"], h2, cfg)
        else:
            y = apply_mlp(p["ffn"], h2, cfg.mlp_activation)
        x = x + y
    x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    head = params["lm_head"] if "lm_head" in params else params["embed"]["table"].T
    logits = unembed(head, x[:, 0], cfg.logit_softcap)       # (N, V)
    return logits, pool_k, pool_v, pool_pos


def supports_paged(cfg: ModelConfig) -> bool:
    return (cfg.mla is None and cfg.encoder is None
            and all(k in (ATTN, LOCAL_ATTN) for k in cfg.layer_kinds))
