"""Token sampling: greedy / temperature (host-side numpy on small logits)."""

from __future__ import annotations

import numpy as np


def sample_token(logits: np.ndarray, temperature: float,
                 rng: np.random.Generator) -> int:
    if temperature <= 0.0:
        return int(np.argmax(logits))
    z = logits.astype(np.float64) / temperature
    z = z - z.max()
    p = np.exp(z)
    p /= p.sum()
    return int(rng.choice(len(p), p=p))
