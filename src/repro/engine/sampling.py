"""Token sampling: greedy / temperature with top-k and top-p (nucleus)
filtering — host-side numpy on small logits.

Each request carries its own :class:`SamplingParams` and its own
``np.random.Generator`` seeded from ``(engine_seed, rid)``, so sampled
output is a function of the request alone — independent of batch
composition and admission order under continuous batching.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration, threaded through the engine's
    decode streams.

    ``top_k``: keep only the k highest logits (0 disables). ``top_p``:
    nucleus sampling — keep the smallest set of tokens whose cumulative
    probability reaches p (1.0 disables). Filters apply to the
    temperature-scaled distribution (vLLM/HF processor order, so
    configs port across); greedy decoding (``temperature <= 0``)
    ignores them.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")


def top_k_filter(logits: np.ndarray, k: int) -> np.ndarray:
    """Mask all but the k highest logits to -inf (ties at the k-th value
    are all kept, matching the usual threshold formulation)."""
    if k <= 0 or k >= logits.size:
        return logits
    kth = np.partition(logits, -k)[-k]
    return np.where(logits >= kth, logits, -np.inf)


def top_p_filter(logits: np.ndarray, p: float) -> np.ndarray:
    """Nucleus filter: keep the smallest descending-probability prefix
    whose cumulative mass reaches ``p`` (the first token always
    survives); everything else goes to -inf."""
    if p >= 1.0:
        return logits
    order = np.argsort(logits)[::-1]
    z = logits[order].astype(np.float64)
    z = z - z.max()
    probs = np.exp(z)
    probs /= probs.sum()
    cum = np.cumsum(probs)
    keep = (cum - probs) < p  # cumulative mass *before* this token
    out = np.full_like(logits, -np.inf, dtype=np.float64)
    out[order[keep]] = logits[order[keep]]
    return out


def sample_token(logits: np.ndarray, temperature: float,
                 rng: np.random.Generator, top_k: int = 0,
                 top_p: float = 1.0) -> int:
    if temperature <= 0.0:
        return int(np.argmax(logits))
    # temperature first, then filters: the nucleus must be chosen on the
    # distribution actually sampled from (top-k is scale-invariant, but
    # a flat high-temperature distribution has a wider nucleus)
    z = np.asarray(logits, np.float64) / temperature
    if top_k > 0:
        z = top_k_filter(z, top_k)
    if top_p < 1.0:
        z = top_p_filter(z, top_p)
    z = z - z[np.isfinite(z)].max()
    p = np.exp(z)
    p /= p.sum()
    return int(rng.choice(len(p), p=p))
