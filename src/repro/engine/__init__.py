from .engine import EngineConfig, GenResult, MedVerseEngine, SerialEngine
from .kvcache import (IndexChain, OutOfPagesError, PageAllocator, PoolConfig,
                      init_pool)
from .paged_model import (paged_decode, prefill_forward, prefix_pool_write,
                          supports_paged)
from .radix import RadixTree

__all__ = [
    "EngineConfig",
    "OutOfPagesError",
    "prefix_pool_write",
    "GenResult",
    "MedVerseEngine",
    "SerialEngine",
    "IndexChain",
    "PageAllocator",
    "PoolConfig",
    "init_pool",
    "paged_decode",
    "prefill_forward",
    "supports_paged",
    "RadixTree",
]
