from .engine import (EngineConfig, GenResult, MedVerseEngine, SerialEngine,
                     StepEvent)
from .kvcache import (IndexChain, OutOfPagesError, PageAllocator, PoolConfig,
                      init_pool)
from .paged_model import (ATTENTION_BACKENDS, check_backend,
                          decode_attention_dense, paged_decode,
                          prefill_forward, prefix_pool_write, supports_paged)
from .radix import RadixTree
from .sampling import SamplingParams, sample_token
from .spec import DRAFTERS, Drafter, NgramDrafter, RadixDrafter, make_drafter

__all__ = [
    "EngineConfig",
    "StepEvent",
    "SamplingParams",
    "sample_token",
    "OutOfPagesError",
    "prefix_pool_write",
    "GenResult",
    "MedVerseEngine",
    "SerialEngine",
    "IndexChain",
    "PageAllocator",
    "PoolConfig",
    "init_pool",
    "ATTENTION_BACKENDS",
    "check_backend",
    "decode_attention_dense",
    "paged_decode",
    "prefill_forward",
    "supports_paged",
    "RadixTree",
    "DRAFTERS",
    "Drafter",
    "NgramDrafter",
    "RadixDrafter",
    "make_drafter",
]
