"""MedVerse Engine: two-phase hybrid execution with continuous batching
(paper Sec. 4.3).

Phase I  — *Linear planning*: standard AR decode per request until the
``</Plan>`` token; the engine then parses the <Outline> dependencies and
instantiates the Petri net (graph initialization).

Phase II — *Frontier-based graph execution*: at each marking M_k the
enabled-transition frontier F_k (Eq. 1) is spawned as parallel decode
streams. **Fork** streams share the parent context via index-chain copy
(zero device copies); **Join** streams merge predecessor chains with
ordered dedup over pool slots (shared ancestors counted once — the
"flexible radix cache layout, no padding or physical copy" claim).
Adaptive positions: every stream in a frontier starts at the max end
position of all completed work (fork alignment / join-max, Sec. 4.2).

All active streams across all requests and phases decode together in one
batched ``paged_decode`` call per iteration — continuous batching.

Step-level API
--------------

The engine itself is an open system: requests enter and leave mid-flight.

* ``add_request(prompt, plan, sampling) -> rid`` — prefill and admit one
  request into the running batch (raises :class:`OutOfPagesError` if the
  prompt cannot be prefilled even after cache eviction).
* ``step() -> list[StepEvent]`` — one batched decode iteration over all
  active streams; emits ``token`` events (per stream token), ``done``
  events (request finished, carries the :class:`GenResult`) and
  ``preempted`` events (see below).
* ``abort(rid)`` / ``has_capacity()`` / ``n_free_slots()``.

``generate()`` is a thin closed-batch wrapper over this API (admit while
slots are free, step until drained) — temperature-0 output is
bit-identical to the historical closed-batch loop.

Preemption: when the page pool runs dry mid-step (after radix-cache
eviction — pinned cache pages always go first), the step rolls back its
partial slot reservations, releases the *youngest* live request's chains
and emits a ``preempted`` event instead of crashing. The caller (the
serving scheduler, or ``generate`` itself) re-queues the victim for
re-prefill — cheap, because its prompt usually still sits in the radix
cache.

Reproducible sampling: each request draws from its own
``np.random.Generator`` seeded from ``(engine_seed, rid)``, so
temperature>0 output is independent of batch composition and admission
order; per-request :class:`SamplingParams` add top-k / top-p filtering.

Scheduler modes
---------------

* ``async_frontier=False`` (paper default): frontier-synchronized. The
  marking only advances when the whole frontier F_k has finished; every
  stream of F_{k+1} starts at the global join-max position.
* ``async_frontier=True``: per-transition marking advance. Each firing
  immediately spawns whichever successors just became enabled
  (``PetriScheduler.ready``), so short branches stop gating long ones.
  Spawn positions use the join-max over the transition's *own*
  predecessors — on DAGs where every join covers its frontier (diamond,
  fan-out) this is the same position the synchronized path uses, so
  temperature-0 output text is identical; on mixed-depth DAGs the engine
  finishes in strictly fewer decode iterations.
* ``radix_cache=True``: cross-request prefix reuse. Prefill consults the
  radix tree before allocating (cache hits adopt existing pool slots) and
  inserts the prompt afterwards; cached pages are pinned in the
  allocator (``PageAllocator.pin``) and evicted LRU under page pressure.
* ``speculative=True``: per-chain speculative decoding (see ``spec.py``
  and ``docs/ARCHITECTURE.md``). Each live stream may feed a *block* of
  rows into the batched decode — queued forced tokens plus drafter
  proposals — verified in the same ``paged_decode`` call and committed
  as the longest argmax-accepted prefix, with rejected slots rolled
  back. Temperature-0 output text is bit-identical on or off; only the
  decode-iteration count changes.
* chain bucketing: every decode step pads chains to the smallest
  power-of-two bucket (>= ``min_chain_bucket``, capped at
  ``max_chain_len``) covering the batch, instead of always paying
  ``max_chain_len``-wide attention; ``warmup()`` pre-compiles the bucket
  ladder so no request hits XLA compilation mid-generation.

Page lifetime: the engine releases every chain a request held when it
finishes (or is aborted / preempted), so ``PageAllocator.used`` returns
to its pre-request level; only radix-pinned prompt pages persist, as
reclaimable cache.
"""

from __future__ import annotations

import dataclasses
import os
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.dag import CycleError, ReasoningDAG
from ..core.petri import ColoredToken, PetriNet, PetriScheduler
from ..core.plan import PlanParseError, parse_plan
from ..data.tokenizer import EOS, Tokenizer
from ..models.config import ModelConfig
from ..obs.audit import (DISPOSITIONS, VERDICT_STATUSES, AuditRecord,
                         AuditTrail)
from ..obs.cost import CompileWatcher, CostGeometry, CostLedger
from ..obs.metrics import MetricsRegistry
from ..obs.trace import NULL_RECORDER, TraceRecorder
from .kvcache import (IndexChain, OutOfPagesError, PageAllocator, PoolConfig,
                      init_pool, pages_for_budget)
from .paged_model import (check_backend, paged_decode, prefill_forward,
                          prefix_pool_write, prefix_pool_write_quant,
                          supports_paged)
from .radix import RadixTree
from .sampling import SamplingParams, sample_token
from .spec import Drafter, make_drafter


@dataclasses.dataclass
class EngineConfig:
    max_slots: int = 8
    page_size: int = 16
    n_pages: int = 4096
    # KV pool storage dtype: "f32" keeps K/V in the model dtype;
    # "int8" stores K/V as int8 with one float32 absmax scale per
    # (layer, page, kv_head) — the pool body shrinks 4x, both attention
    # backends dequantize on read (the pallas path in VMEM, inside the
    # kernel), and temperature-0 decoding stays on the same argmax
    # (quantization noise is bounded by the per-page absmax contract;
    # pinned by tests/test_kv_quant.py). Defaults from $ENGINE_KV_DTYPE
    # so the full test/bench surface runs under either pool unmodified
    # (the CI matrix sets it).
    kv_dtype: str = dataclasses.field(
        default_factory=lambda: os.environ.get("ENGINE_KV_DTYPE", "f32"))
    # byte budget for the KV pool: when set, ``n_pages`` is ignored and
    # derived as kv_pool_bytes // PoolConfig.page_bytes (int8 scale
    # arrays included) — the honest way to compare pool dtypes at equal
    # memory: an int8 pool holds ~4x the pages, so the same budget
    # admits more live chains and preempts strictly less often under
    # pressure.
    kv_pool_bytes: Optional[int] = None
    # chunked prefill: when > 0, a prompt whose uncached suffix is
    # longer than this many tokens skips the monolithic
    # ``prefill_forward`` call and instead queues the suffix on its
    # stream; the regular batched decode step ingests it as prompt rows
    # (at most ``prefill_chunk`` per stream per step, and only into
    # batch rows the step would otherwise pad), so admitted requests
    # keep decoding while a long prompt fills its pages incrementally —
    # no head-of-line stall, no new compiled shapes. 0 keeps every
    # prompt on the monolithic bucketed prefill.
    prefill_chunk: int = 0
    max_chain_len: int = 640
    min_chain_bucket: int = 64     # smallest power-of-two decode bucket
    max_plan_tokens: int = 256
    max_step_tokens: int = 64
    max_conclusion_tokens: int = 96
    max_serial_tokens: int = 512
    temperature: float = 0.0
    # False: frontier-synchronized (paper default). True: per-transition
    # marking advance — successors spawn as soon as their own
    # predecessors fire (see module docstring, "Scheduler modes").
    async_frontier: bool = False
    radix_cache: bool = True       # cross-request prompt-prefix reuse
    # "dense": gather chains + masked jnp SDPA (reference semantics).
    # "pallas": paged flash decode kernel + chunked DAG prefill kernel
    # (the TPU hot path; see paged_model docstring for the parity
    # contract). Defaults from $ENGINE_ATTENTION_BACKEND so the full
    # test/bench surface runs under either backend unmodified (the CI
    # matrix sets it).
    attention_backend: str = dataclasses.field(
        default_factory=lambda: os.environ.get(
            "ENGINE_ATTENTION_BACKEND", "dense"))
    # run Pallas kernels in interpret mode (CPU-executable emulation);
    # set False on real TPU hardware for compiled Mosaic kernels
    kernel_interpret: bool = True
    seed: int = 0
    # safety valve: a request evicted this many times is genuinely too
    # large for the pool — step() raises instead of thrashing
    max_preemptions: int = 16
    # Speculative decoding (see spec.py and docs/ARCHITECTURE.md):
    # every live stream may feed up to 1 + draft_len tokens per step —
    # queued forced tokens (teacher-forced plans, step headers) batched
    # unconditionally, then drafter proposals verified against the
    # argmax of the same batched decode call. Draft rows only occupy
    # batch rows the step would otherwise pad, so the compiled shapes
    # (max_slots rows, the chain-bucket ladder) are reused as-is.
    # Temperature-0 output text is bit-identical with this on or off.
    speculative: bool = False
    drafter: str = "ngram"         # "ngram" | "radix" (spec.DRAFTERS)
    draft_len: int = 4             # max draft rows per stream per step
    # Teacher-forced plan injection: skip LLM planning and force this
    # plan text (deterministic execution; also the Table-5 "Direct Petri
    # Net" ablation hook and the debugging surface).
    plan_override: Optional[str] = None
    # Observability (src/repro/obs/): truthy enables the structured
    # trace recorder — span/instant/counter events with two clocks
    # (wall seconds + deterministic decode step) from the engine,
    # page allocator, radix tree, spec path, and serving scheduler.
    # A string is the default dump path for ``dump_trace()`` (JSONL +
    # Chrome trace-event export); ``True`` records in memory only.
    # Tracing is passive: temperature-0 output is bit-identical with
    # it on or off (pinned by tests/test_obs.py). Default off — every
    # hook short-circuits through the no-op recorder.
    trace: Optional[str] = None
    # Analytic cost accounting (src/repro/obs/cost.py): per-step
    # attention FLOPs, KV bytes, page gathers, and padding waste,
    # attributed per phase (prefill / decode / spec_verify) and per
    # request from engine-native integers — machine-independent, so CI
    # gates the totals exactly. Plain-int adds on the host path (same
    # cost class as bucket_hist); passive like tracing (pinned by
    # tests/test_cost.py). Default on — the live /metrics endpoint and
    # ServingReport.engine read it.
    cost_accounting: bool = True
    # Clinical audit trail (src/repro/obs/audit.py): truthy enables the
    # AuditTrail — one deterministic rule-extracted verdict per finished
    # critic/guardrail stream, plus a per-request disposition
    # (verified | refuted | unverified) when the request closes. A
    # string is the default dump path for ``dump_audit()``
    # (medverse-audit/1 JSONL); ``True`` records in memory only.
    # Passive like tracing: temp-0 output and iteration counts are
    # bit-identical with auditing on or off (pinned by
    # tests/test_audit.py). Independent of ``trace`` — when both are
    # on, audit records also mirror into the trace as cat="audit"
    # instants on the two-clock schema.
    audit: Optional[str] = None


@dataclasses.dataclass
class GenResult:
    text: str
    ok: bool
    n_tokens: int                 # generated tokens (all streams)
    critical_path_tokens: int     # O(D) depth the paper's latency tracks
    wall_s: float
    plan_ok: bool
    topology: str
    timings: Dict[str, float]
    step_texts: Dict[int, str] = dataclasses.field(default_factory=dict)
    conclusion: str = ""


@dataclasses.dataclass
class StepEvent:
    """One observable outcome of an engine ``step()``.

    ``token``: a stream of request ``rid`` consumed one token (``forced``
    marks teacher-forced / header tokens; ``drafted`` marks tokens
    committed from an accepted speculative draft — one step may emit
    several per stream). ``done``: the request finished; ``result``
    carries its :class:`GenResult` and its pages are already released.
    ``preempted``: the request was evicted under page pressure and must
    be re-queued for re-prefill by the caller. ``audit``: the audit
    trail recorded a decision or disposition; ``audit`` carries the
    :class:`~repro.obs.audit.AuditRecord` (only with
    ``EngineConfig.audit`` on).
    """

    kind: str                 # "token" | "done" | "preempted" | "audit"
    rid: int
    token: int = -1
    purpose: str = ""         # "plan" | "step" | "conclusion" | "serial"
    tid: int = -1             # DAG transition id for step streams
    stage: str = ""           # step streams: "reason"|"critic"|"guardrail"
    forced: bool = False
    drafted: bool = False
    result: Optional[GenResult] = None
    audit: Optional[AuditRecord] = None


class _Stream:
    __slots__ = ("chain", "q_pos", "forced", "next_input", "generated",
                 "purpose", "stop_id", "max_new", "done", "finish_after",
                 "n_generated", "rid", "tid", "history", "seq_ok",
                 "stage", "n_header", "priority", "pending_prompt",
                 "n_prompt", "n_cached", "chunk_seq")

    def __init__(self, chain: IndexChain, q_pos: int, purpose: str,
                 rid: int, tid: int = -1, stop_id: int = EOS,
                 max_new: int = 64, history: Optional[List[int]] = None):
        self.chain = chain
        self.q_pos = q_pos
        self.forced: deque = deque()
        self.next_input: Optional[int] = None
        self.generated: List[int] = []
        self.purpose = purpose   # "plan" | "step" | "conclusion" | "serial"
        self.rid = rid
        self.tid = tid
        self.stop_id = stop_id
        self.max_new = max_new
        self.done = False
        self.finish_after = False
        self.n_generated = 0
        # Speculation context: the committed tokens *behind* this
        # stream's chain (prompt / linear ancestor history), when the
        # ancestry is a single linear sequence; None for dedup joins.
        # ``history + generated`` is then the full token view of the
        # chain — the drafter lookup context, and (when ``seq_ok``) a
        # radix-insertable sequence.
        self.history = history
        # positions are gap-free iff the stream starts appending exactly
        # where the chain's content ends (join-max can skip positions)
        self.seq_ok = (q_pos == chain.length)
        # stage typing (step streams only): the transition's stage tag,
        # the forced <Step> header length (the audit body excludes it),
        # and whether this stream won stage-aware decode priority
        self.stage = ""
        self.n_header = 0
        self.priority = False
        # chunked prefill (EngineConfig.prefill_chunk): the not-yet-
        # ingested prompt suffix. While non-empty the stream feeds
        # prompt rows (no sampling, no token events) through the decode
        # step; n_prompt/n_cached/chunk_seq back the per-chunk trace
        # spans and the deferred radix insert.
        self.pending_prompt: deque = deque()
        self.n_prompt = 0
        self.n_cached = 0
        self.chunk_seq = 0


class _Request:
    def __init__(self, rid: int, prompt: str, prompt_ids: List[int],
                 seed: int = 0, sampling: Optional[SamplingParams] = None,
                 plan: Optional[str] = None):
        self.rid = rid
        self.prompt = prompt
        self.prompt_ids = prompt_ids
        self.sampling = sampling or SamplingParams()
        # per-request generator: output depends on (engine_seed, rid)
        # only, never on batch composition or admission order
        self.rng = np.random.default_rng((seed, rid))
        self.plan_spec = plan      # teacher-forced plan text, if any
        self.plan = None           # parsed ReasoningPlan, set after Phase I
        self.state = "planning"
        self.dag: Optional[ReasoningDAG] = None
        self.sched: Optional[PetriScheduler] = None
        self.labels: Dict[int, str] = {}
        self.ctx_chain: Optional[IndexChain] = None
        self.final_chain: Optional[IndexChain] = None
        self.ctx_end = 0
        self.max_end = 0
        self.step_results: Dict[int, Tuple[str, IndexChain, int]] = {}
        # token-level views used by speculation: the linear context
        # tokens (prompt + plan) and, per fired transition, the full
        # linear token history of its stream (None for join ancestry)
        self.ctx_tokens: Optional[List[int]] = None
        self.step_tokens: Dict[int, Optional[List[int]]] = {}
        self.pending_frontier: List[int] = []
        self.plan_text = ""
        self.conclusion_text = ""
        self.plan_ok = False
        self.t_start = 0.0
        self.timings = {"planning": 0.0, "execution": 0.0,
                        "conclusion": 0.0, "fork_join": 0.0,
                        "schedule_parse": 0.0}
        self.n_tokens = 0
        self.done = False


class MedVerseEngine:
    def __init__(self, params, cfg: ModelConfig, tok: Tokenizer,
                 ecfg: Optional[EngineConfig] = None):
        assert supports_paged(cfg), (
            f"{cfg.name}: engine paged path requires attention layers "
            "(SSM/MLA archs use models.decode_step; see DESIGN.md §4)")
        self.params = params
        self.cfg = cfg
        self.tok = tok
        self.ecfg = ecfg or EngineConfig()
        check_backend(cfg, self.ecfg.attention_backend)
        if self.ecfg.kv_dtype not in ("f32", "int8"):
            raise ValueError(
                f"kv_dtype={self.ecfg.kv_dtype!r}: expected 'f32' or "
                "'int8'")
        pc = PoolConfig(
            n_layers=cfg.n_layers, n_pages=self.ecfg.n_pages,
            page_size=self.ecfg.page_size, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, dtype=cfg.dtype,
            kv_dtype=self.ecfg.kv_dtype,
        )
        if self.ecfg.kv_pool_bytes is not None:
            # size the pool by bytes, not pages: page_bytes includes the
            # int8 scale arrays, so dtypes compare at honest equal memory
            pc = dataclasses.replace(
                pc, n_pages=pages_for_budget(pc, self.ecfg.kv_pool_bytes))
        self.pc = pc
        self._quantized = pc.quantized
        self.pool = init_pool(pc)
        self.alloc = PageAllocator(pc)
        self.radix = RadixTree(page_size=pc.page_size,
                               on_pin=self.alloc.pin,
                               on_unpin=self.alloc.unpin)
        # under page pressure, reclaim radix-pinned cache pages (LRU)
        self.alloc.reclaim_cb = self.radix.evict_one
        # observability: one recorder shared by every component (engine,
        # allocator, radix, spec path, serving scheduler). Off by
        # default — NULL_RECORDER makes every hook a single attribute
        # check (``if obs.enabled``), so the hot path stays untouched.
        self.obs = TraceRecorder() if self.ecfg.trace else NULL_RECORDER
        if self.obs.enabled:
            self.obs.meta(
                model=cfg.name,
                attention_backend=self.ecfg.attention_backend,
                kv_dtype=self.ecfg.kv_dtype,
                prefill_chunk=self.ecfg.prefill_chunk,
                n_pages=pc.n_pages, page_size=self.ecfg.page_size,
                max_slots=self.ecfg.max_slots,
                speculative=self.ecfg.speculative,
                async_frontier=self.ecfg.async_frontier)
            self.alloc.tracer = self.obs
            self.radix.tracer = self.obs
        # analytic cost model + compiled-shape watcher (obs/cost.py):
        # both are plain-int host accounting over values the hot path
        # already computes, independent of tracing — the watcher is
        # always on (its counters back the bucket-ladder CI gate)
        self.cost: Optional[CostLedger] = (
            CostLedger(CostGeometry.from_model(
                cfg, pc.page_size, self.ecfg.max_slots,
                "int8" if pc.quantized else pc.dtype))
            if self.ecfg.cost_accounting else None)
        self.compiles = CompileWatcher()
        # clinical audit trail (obs/audit.py): one rule-extracted verdict
        # per finished critic/guardrail stream, one disposition per
        # request. Passive like tracing — it reads decoded text and the
        # step clock only, never RNG / pages / scheduling state.
        self.audit: Optional[AuditTrail] = (
            AuditTrail(obs=self.obs,
                       meta={"model": cfg.name,
                             "attention_backend":
                                 self.ecfg.attention_backend})
            if self.ecfg.audit else None)
        # speculative decoding: one drafter shared by every stream; the
        # radix drafter reads (and populates, via generation caching)
        # the same radix tree the prefill cache uses
        self._drafter: Optional[Drafter] = None
        if self.ecfg.speculative:
            self._drafter = make_drafter(self.ecfg.drafter, self.radix)
            if self._drafter.wants_generation_cache and not self.ecfg.radix_cache:
                raise ValueError(
                    "drafter='radix' requires radix_cache=True (the radix "
                    "tree is its draft source)")
        # lifetime speculation counters: draft rows proposed/accepted,
        # extra forced rows batched, committed tokens, decode steps
        self.spec_stats: Dict[str, int] = {
            "proposed": 0, "accepted": 0, "forced_batched": 0,
            "tokens": 0, "steps": 0}
        self.last_iters = 0                  # decode iterations, last generate()
        self.total_iters = 0                 # decode iterations, lifetime
        self.preemptions = 0                 # page-pressure evictions, lifetime
        self.bucket_hist: Dict[int, int] = {}  # chain bucket -> decode steps
        self.page_bucket_hist: Dict[int, int] = {}  # pallas: P_max -> steps
        # open-system state: live requests and their decode streams
        self._reqs: Dict[int, _Request] = {}
        self._active: List[_Stream] = []
        self._next_rid = 0
        self._preempt_count: Dict[int, int] = {}
        self.id_plan_end = tok.token_id("</Plan>")
        self.id_step_end = tok.token_id("</Step>")
        self.id_conc_end = tok.token_id("</Conclusion>")
        self.id_exec = tok.token_id("<Execution>")
        self.id_conc = tok.token_id("<Conclusion>")

    # ------------------------------------------------------------ prefill --
    PREFILL_BUCKET = 64

    def _prefill(self, req: _Request) -> _Stream:
        ids = req.prompt_ids
        n = len(ids)
        obs = self.obs
        t0 = obs.now() if obs.enabled else 0.0
        chain = IndexChain.fresh(self.alloc)
        cached = np.zeros((0,), np.int32)
        path: List = []
        if self.ecfg.radix_cache:
            # cross-request prefix reuse: adopt cached pool slots instead
            # of allocating; always recompute >= 1 token for the logits
            cached, path = self.radix.match_prefix(ids)
            cached = cached[: n - 1]
            # adopt whole pages only (block-aligned, vLLM-style). With a
            # quantized pool this is load-bearing, not just tidy: a
            # partially matched page dequantizes under a scale computed
            # from the writer's co-resident rows — rows this request
            # never matched — so its values would depend on batch
            # history. Whole-page adoption keeps every adopted scale a
            # pure function of the matched tokens, and doing it for f32
            # too keeps adoption (and the exact byte accounting)
            # identical across kv dtypes.
            keep = (cached.size // self.pc.page_size) * self.pc.page_size
            cached = cached[:keep]
            chain.adopt(cached)
        m = int(cached.size)
        if (self.ecfg.prefill_chunk > 0
                and n - m > self.ecfg.prefill_chunk):
            return self._admit_chunked(req, chain, path, m)
        try:
            new_slots = chain.reserve(n - m)
        except OutOfPagesError:
            # admission failure must not leak: drop the partial chain and
            # the radix lookup leases before surfacing the pressure
            if self.ecfg.radix_cache:
                self.radix.release(path)
            chain.release()
            raise
        # bucket the prompt length so one compilation serves many prompts
        bucket = -(-n // self.PREFILL_BUCKET) * self.PREFILL_BUCKET
        ids_p = np.zeros((bucket,), np.int32)
        ids_p[:n] = ids
        pos_p = np.arange(bucket, dtype=np.int32)
        new_shape = self.compiles.note(
            ("prefill", self.ecfg.attention_backend, bucket))
        t_c = obs.now() if (obs.enabled and new_shape) else 0.0
        logits, ks, vs = prefill_forward(
            self.params, jnp.asarray(ids_p)[None],
            jnp.asarray(pos_p)[None], self.cfg, jnp.int32(n),
            backend=self.ecfg.attention_backend,
            interpret=self.ecfg.kernel_interpret)
        if new_shape and obs.enabled:
            obs.complete("compile", "compile", t_c, kind="prefill",
                         backend=self.ecfg.attention_backend,
                         bucket=bucket,
                         after_warmup=self.compiles.warmup_step is not None)
        # write only positions [m, n): the cached prefix already holds
        # identical K/V; prefix and padding rows get the out-of-range
        # sentinel slot and are dropped device-side
        wslots = np.full((bucket,), self.pc.n_slots, np.int32)
        wslots[m:n] = new_slots
        if self._quantized:
            (self.pool["k"], self.pool["v"], self.pool["pos"],
             self.pool["k_scale"], self.pool["v_scale"]) = (
                prefix_pool_write_quant(
                    self.pool["k"], self.pool["v"], self.pool["pos"],
                    self.pool["k_scale"], self.pool["v_scale"],
                    ks, vs, jnp.asarray(wslots), jnp.asarray(pos_p),
                    page_size=self.pc.page_size))
        else:
            self.pool["k"], self.pool["v"], self.pool["pos"] = (
                prefix_pool_write(
                    self.pool["k"], self.pool["v"], self.pool["pos"],
                    ks, vs, jnp.asarray(wslots), jnp.asarray(pos_p)))
        if self.ecfg.radix_cache:
            self.radix.insert(ids, chain.idx[:n])
            # pages are pinned via the allocator; lookup refs can go
            self.radix.release(path)
        st = _Stream(chain, q_pos=n, purpose="plan", rid=req.rid,
                     stop_id=self.id_plan_end,
                     max_new=self.ecfg.max_plan_tokens,
                     history=list(ids))
        if req.plan_spec is not None:
            forced = self.tok.encode(req.plan_spec)
            st.forced.extend(forced)
            st.max_new = len(forced) + 2
        sp = req.sampling
        st.next_input = int(sample_token(
            np.asarray(logits), sp.temperature, req.rng, sp.top_k, sp.top_p))
        if self.cost is not None:
            self.cost.note_prefill(req.rid, n_prompt=n, n_cached=m,
                                   bucket=bucket)
            if obs.enabled:
                self.cost.emit(obs)
        if obs.enabled:
            obs.complete("prefill", "engine", t0, rid=req.rid,
                         n_prompt=n, n_cached=m, bucket=bucket)
        return st

    def _admit_chunked(self, req: _Request, chain: IndexChain,
                       path: List, m: int) -> _Stream:
        """Admit a long prompt without running monolithic prefill.

        The uncached suffix ``ids[m:]`` is queued on the stream and
        flows through the regular batched decode step as prompt rows —
        at most ``prefill_chunk`` per step, only into batch rows the
        step would otherwise pad (:meth:`_plan_blocks`), writing pool
        pages incrementally with the ordinary per-row decode writes.
        No pages are reserved here (the step's slot reservation handles
        pressure, so a preemption mid-prefill rolls back like any other
        step) and no new shapes compile (chunk rows reuse the decode
        bucket ladder). Prompt ingest cost lands on the ledger's
        ``prefill`` phase via the per-row decode attribution. The radix
        insert is deferred until the last prompt row commits
        (:meth:`_finish_chunked_prefill`) — the tree never indexes a
        half-prefilled prompt; the lookup leases can go now because
        ``adopt`` already increfed the cached pages."""
        ids = req.prompt_ids
        n = len(ids)
        if self.ecfg.radix_cache:
            self.radix.release(path)
        st = _Stream(chain, q_pos=m, purpose="plan", rid=req.rid,
                     stop_id=self.id_plan_end,
                     max_new=self.ecfg.max_plan_tokens,
                     history=list(ids))
        st.pending_prompt = deque(int(t) for t in ids[m:])
        st.n_prompt = n
        st.n_cached = m
        if req.plan_spec is not None:
            forced = self.tok.encode(req.plan_spec)
            st.forced.extend(forced)
            st.max_new = len(forced) + 2
        if self.obs.enabled:
            self.obs.instant("prefill_chunked", "engine", rid=req.rid,
                             n_prompt=n, n_cached=m,
                             chunk=self.ecfg.prefill_chunk)
        return st

    def _finish_chunked_prefill(self, req: _Request, st: _Stream) -> None:
        """Last prompt row of a chunked prefill just committed: the
        chain now covers the whole prompt gap-free, so it is safe to
        index in the radix tree (same insert the monolithic path does
        eagerly)."""
        if self.ecfg.radix_cache:
            ids = req.prompt_ids
            self.radix.insert(ids, st.chain.idx[: len(ids)])

    # --------------------------------------------------------- fork/join ---
    def _start_pos(self, req: _Request, t) -> int:
        """Join-max adaptive position over t's own predecessors (the
        async per-transition advance); the sync path instead starts every
        frontier stream at the global ``req.max_end``."""
        ends = []
        for p in t.pre:
            if p == req.sched.net.ctx_place:
                ends.append(req.ctx_end)
            else:
                ends.append(req.step_results[self._tid_of_place(req, p)][2])
        return max(ends)

    def _spawn_transition(self, req: _Request, t, start_pos: int) -> _Stream:
        tf = time.monotonic()
        history: Optional[List[int]] = None
        if len(t.pre) == 1:
            if t.pre[0] == req.sched.net.ctx_place:
                src, history = req.ctx_chain, req.ctx_tokens
            else:
                pre_tid = self._tid_of_place(req, t.pre[0])
                src = req.step_results[pre_tid][1]
                history = req.step_tokens.get(pre_tid)
            chain = src.fork()
        else:
            chains = [req.step_results[self._tid_of_place(req, p)][1]
                      for p in t.pre]
            chain = self._dedup_join(chains)
        req.timings["fork_join"] += time.monotonic() - tf
        header = self.tok.encode(
            f"<Step> Transient Step {t.tid + 1}: {req.labels.get(t.tid, '')}")
        st = _Stream(chain, q_pos=start_pos, purpose="step",
                     rid=req.rid, tid=t.tid, stop_id=self.id_step_end,
                     max_new=self.ecfg.max_step_tokens + len(header),
                     history=history)
        st.stage = t.stage
        st.n_header = len(header)
        st.forced.extend(header)
        if self.obs.enabled:
            self._obs_stream_begin(st)
        return st

    def _spawn_ready(self, req: _Request) -> List[_Stream]:
        """Spawn every enabled-and-unclaimed transition. Sync mode calls
        this only at frontier barriers (whole-frontier claim at the
        global join-max position); async mode calls it after every
        individual firing (per-transition join-max)."""
        t0 = time.monotonic()
        fj_before = req.timings["fork_join"]
        ready = req.sched.ready()
        if not ready:
            return []
        # stage-aware dispatch: a ready critic whose verdict gates >= 2
        # sibling branches (frontier-unblocking count from the Petri
        # marking) spawns first and keeps decode priority under slot
        # over-subscription — its verdict lands sooner, so the branches
        # it unblocks start sooner. Deterministic (marking-only) and
        # independent of auditing; plans without critic stages take the
        # sorted-tid path unchanged.
        prio: Dict[int, int] = {}
        for t in ready:
            if t.stage == "critic":
                n_unb = req.sched.unblock_count(t)
                if n_unb >= 2:
                    prio[t.tid] = n_unb
                    if self.obs.enabled:
                        self.obs.instant(
                            "critic_priority", "engine", rid=req.rid,
                            tid=t.tid, unblocks=n_unb)
        if prio:
            ready = sorted(ready,
                           key=lambda t: (-prio.get(t.tid, 0), t.tid))
        req.sched.history.append([t.tid for t in ready])
        streams = []
        for t in ready:
            start = (self._start_pos(req, t) if self.ecfg.async_frontier
                     else req.max_end)
            req.sched.claim(t)
            st = self._spawn_transition(req, t, start)
            st.priority = t.tid in prio
            streams.append(st)
        req.pending_frontier.extend(s.tid for s in streams)
        fj_delta = req.timings["fork_join"] - fj_before
        req.timings["schedule_parse"] += time.monotonic() - t0 - fj_delta
        return streams

    def _tid_of_place(self, req: _Request, place: int) -> int:
        # PetriNet.from_dag: output place of transition t is t + 1
        return place - 1

    def _dedup_join(self, chains: List[IndexChain]) -> IndexChain:
        """Ordered dedup over pool slots: shared ancestors once, branch
        suffixes in order. Zero device copies."""
        alloc = chains[0].alloc
        out = IndexChain(alloc)
        seen = dict()
        parts = []
        pages = set()
        for ch in chains:
            arr = ch.idx[:ch.length]
            mask = np.fromiter((int(s) not in seen for s in arr), bool,
                               count=len(arr))
            for s in arr[mask]:
                seen[int(s)] = True
            parts.append(arr[mask])
            pages |= ch.pages
        out.idx = (np.concatenate(parts).astype(np.int32)
                   if parts else np.zeros((0,), np.int32))
        out.length = int(out.idx.shape[0])
        out.pages = pages
        for pg in pages:
            alloc.incref(pg)
        return out

    def _spawn_conclusion(self, req: _Request) -> _Stream:
        tf = time.monotonic()
        chains = [req.ctx_chain] + [req.step_results[t][1]
                                    for t in sorted(req.step_results)]
        chain = self._dedup_join(chains)
        req.timings["fork_join"] += time.monotonic() - tf
        st = _Stream(chain, q_pos=req.max_end, purpose="conclusion",
                     rid=req.rid, stop_id=self.id_conc_end,
                     max_new=self.ecfg.max_conclusion_tokens)
        st.forced.append(self.id_conc)
        if self.obs.enabled:
            self._obs_stream_begin(st)
        return st

    # ------------------------------------------------------- stream done ---
    def _observe_stream(self, st: _Stream) -> None:
        """Feed a finished stream to the drafter, and — for the radix
        drafter — insert it into the radix prefix cache so later
        requests can draft (and prefill) from it. Only streams whose
        ancestry is one linear sequence *and* whose positions are
        gap-free are insertable: the tree maps token sequences to pool
        slots whose stored (RoPE'd) positions must read ``0..n-1`` for
        a future prefill adoption to be correct."""
        if self._drafter is None:
            return
        if st.history is not None:
            toks = st.history + st.generated
            self._drafter.observe(toks)
            if (self._drafter.wants_generation_cache and st.seq_ok
                    and len(toks) == st.chain.length):
                self.radix.insert(toks, st.chain.idx[: st.chain.length])
        else:
            self._drafter.observe(st.generated)

    def _on_stream_done(self, req: _Request, st: _Stream,
                        new_streams: List[_Stream]) -> None:
        text = self.tok.decode(st.generated)
        if st.history is not None:
            if st.purpose == "plan":
                req.ctx_tokens = st.history + st.generated
            elif st.purpose == "step":
                req.step_tokens[st.tid] = st.history + st.generated
        self._observe_stream(st)
        if st.purpose == "plan":
            req.plan_text = text
            t0 = time.monotonic()
            try:
                plan = parse_plan(text, lenient=True)
                dag = plan.to_dag()
                req.plan = plan
                req.dag = dag
                req.labels = plan.labels()
                net = PetriNet.from_dag(dag, req.labels)
                req.sched = PetriScheduler(
                    net, ColoredToken(history=text, kv_ref=st.chain))
                req.plan_ok = True
                req.state = "executing"
                req.ctx_chain = st.chain
                req.ctx_end = st.q_pos
                req.max_end = st.q_pos
            except (PlanParseError, CycleError):
                # graceful fallback: no valid plan -> go straight to a
                # conclusion over the linear context (serial behaviour)
                req.plan_ok = False
                req.state = "concluding"
                req.ctx_chain = st.chain
                req.ctx_end = st.q_pos
                req.max_end = st.q_pos
                req.step_results = {}
            req.timings["schedule_parse"] += time.monotonic() - t0
            if req.state == "executing":
                new_streams.extend(self._spawn_ready(req))
            else:
                new_streams.append(self._spawn_conclusion(req))
        elif st.purpose == "step":
            # fire the transition: output token carries (text, chain)
            tr = req.sched.net.transition(st.tid)
            req.sched.fire(tr, ColoredToken(history=text, kv_ref=st.chain))
            req.step_results[st.tid] = (text, st.chain, st.q_pos)
            req.max_end = max(req.max_end, st.q_pos)
            req.pending_frontier.remove(st.tid)
            # sync: advance the marking only at the frontier barrier;
            # async: every firing may enable successors immediately
            if self.ecfg.async_frontier or not req.pending_frontier:
                nxt = self._spawn_ready(req)
                new_streams.extend(nxt)
                if not nxt and not req.pending_frontier:
                    req.state = "concluding"
                    new_streams.append(self._spawn_conclusion(req))
        elif st.purpose in ("conclusion", "serial"):
            req.conclusion_text = text
            req.final_chain = st.chain
            req.done = True

    # ------------------------------------------------- step-level API ------
    def has_capacity(self) -> bool:
        """True if one more request can start decoding immediately."""
        return len(self._active) < self.ecfg.max_slots

    def n_free_slots(self) -> int:
        return max(self.ecfg.max_slots - len(self._active), 0)

    def n_requests(self) -> int:
        return len(self._reqs)

    @property
    def active_rids(self) -> List[int]:
        return sorted(self._reqs)

    def add_request(self, prompt: str, plan: Optional[str] = None,
                    sampling: Optional[SamplingParams] = None,
                    rid: Optional[int] = None) -> int:
        """Prefill and admit one request into the running batch.

        ``plan`` teacher-forces the planning phase (defaults to
        ``EngineConfig.plan_override``). ``rid`` pins the request id —
        used when re-admitting a preempted request so its sampling seed
        (and radix-cached prompt) are reused. Raises
        :class:`OutOfPagesError` when the prompt cannot be prefilled even
        after cache eviction; the engine state is unchanged in that case.
        """
        if rid is None:
            rid = self._next_rid
        if rid in self._reqs:
            raise ValueError(f"request id {rid} is already live")
        self._next_rid = max(self._next_rid, rid + 1)
        req = _Request(
            rid, prompt, self.tok.encode(prompt, bos=True),
            seed=self.ecfg.seed,
            sampling=sampling or SamplingParams(
                temperature=self.ecfg.temperature),
            plan=plan if plan is not None else self.ecfg.plan_override)
        req.t_start = time.monotonic()
        st = self._prefill(req)          # may raise OutOfPagesError
        self._reqs[rid] = req
        self._active.append(st)
        if self.obs.enabled:
            self.obs.begin("request", "request", rid=rid,
                           n_prompt=len(req.prompt_ids))
            self._obs_stream_begin(st)
        return rid

    def abort(self, rid: int) -> bool:
        """Drop a live request and release every page it holds."""
        req = self._reqs.pop(rid, None)
        if req is None:
            return False
        self._drop_streams(rid)
        self._release_request(req)
        if self.audit is not None:
            # an aborted request never reached a conclusion: close its
            # trail with an "unverified" disposition (before the request
            # trace span ends, keeping the instant inside the span)
            self.audit.finish_request(rid, completed=False,
                                      step=self.total_iters)
        if self.obs.enabled:
            extra = ({"cost": self.cost.request_summary(rid)}
                     if self.cost is not None else {})
            self.obs.end("request", "request", rid=rid, reason="aborted",
                         **extra)
        return True

    def _block_capacity(self, st: _Stream) -> int:
        """Most rows stream ``st`` could usefully decode this step: its
        committed input plus up to ``draft_len`` lookahead rows, capped
        by its remaining token budget. Temperature>0 streams batch only
        queued forced tokens (teacher-forced text is distribution-free);
        drafting there would perturb the sampled distribution. A stream
        still ingesting a chunked prompt wants up to ``prefill_chunk``
        prompt rows instead (prompt rows are distribution-free too — no
        temperature cap)."""
        if st.pending_prompt:
            return min(len(st.pending_prompt),
                       max(self.ecfg.prefill_chunk, 1),
                       max(self.ecfg.max_chain_len - st.chain.length, 1))
        if self._drafter is None:
            return 1
        cap = min(1 + self.ecfg.draft_len,
                  max(st.max_new - st.n_generated, 1),
                  # lookahead must not push the chain past the compiled
                  # bucket ladder's max_chain_len cap
                  max(self.ecfg.max_chain_len - st.chain.length, 1))
        if self._reqs[st.rid].sampling.temperature > 0:
            cap = min(cap, max(len(st.forced), 1))
        return cap

    def _build_block(self, st: _Stream, budget: int) -> List[Tuple[int, bool, bool, bool]]:
        """Rows ``(token, was_forced, is_draft, is_prompt)`` stream
        ``st`` feeds into this decode step. A stream mid-chunked-prefill
        contributes only prompt rows (the next ``budget`` tokens of its
        pending suffix — ingested silently, no sampling). Otherwise row
        0 is the committed input (head of the forced queue, else
        ``next_input``); further rows are queued forced tokens, then
        (temperature 0 only) drafter proposals. Forced rows always
        precede draft rows, so the accepted prefix can only break at a
        draft. The block truncates at any terminal token (stop id /
        ``max_new``) — a terminal row is always last.
        """
        if st.pending_prompt:
            k = min(budget, len(st.pending_prompt))
            return [(int(st.pending_prompt[i]), False, False, True)
                    for i in range(k)]
        if st.forced:
            rows = [(int(st.forced[0]), True, False, False)]
            n_forced = 1
        else:
            rows = [(int(st.next_input), False, False, False)]
            n_forced = 0
        ngen = st.n_generated + 1
        if rows[0][0] == st.stop_id or ngen >= st.max_new:
            return rows
        while len(rows) < budget and n_forced < len(st.forced):
            tok = int(st.forced[n_forced])
            rows.append((tok, True, False, False))
            n_forced += 1
            ngen += 1
            if tok == st.stop_id or ngen >= st.max_new:
                return rows
        if (self._drafter is not None and len(rows) < budget
                and n_forced >= len(st.forced)
                and self._reqs[st.rid].sampling.temperature <= 0):
            ctx = ((list(st.history) if st.history is not None else [])
                   + st.generated + [r[0] for r in rows])
            for tok in self._drafter.propose(ctx, budget - len(rows)):
                tok = int(tok)
                rows.append((tok, False, True, False))
                ngen += 1
                if tok == st.stop_id or ngen >= st.max_new:
                    break
        return rows

    def _plan_blocks(self, batch: List[_Stream]) -> List[List[Tuple[int, bool, bool, bool]]]:
        """Split the step's ``max_slots`` batch rows across the active
        streams. Every stream gets its committed-input row; the spare
        rows (the ones a non-speculative step would pad) are dealt
        round-robin to streams that can use them, so every live DAG
        branch speculates in parallel and speculation never displaces a
        stream's real decode. Chunked-prefill streams draw on the same
        spare pool for their prompt rows (capacity ``prefill_chunk``) —
        a long prompt fills the step's padding, never another stream's
        decode row. With speculation off and no prompt pending every
        block is one row — the legacy single-token step, byte for
        byte."""
        if (self._drafter is None
                and not any(st.pending_prompt for st in batch)):
            return [self._build_block(st, 1) for st in batch]
        n = len(batch)
        want = [self._block_capacity(st) for st in batch]
        budgets = [1] * n
        spare = self.ecfg.max_slots - n
        progress = True
        while spare > 0 and progress:
            progress = False
            for i in range(n):
                if spare == 0:
                    break
                if budgets[i] < want[i]:
                    budgets[i] += 1
                    spare -= 1
                    progress = True
        return [self._build_block(st, b) for st, b in zip(batch, budgets)]

    def step(self) -> List[StepEvent]:
        """One continuous-batching iteration: batched ``paged_decode``
        over (up to ``max_slots``) rows spanning the active streams,
        then stream/request completion handling. Returns the step's
        events; an empty list means the engine is idle.

        With ``EngineConfig.speculative`` on, a stream's block may hold
        several rows (see :meth:`_plan_blocks`): queued forced tokens
        batched unconditionally plus drafter proposals verified against
        the argmax of this same decode call. The longest accepted prefix
        is committed (one ``token`` event per row, ``drafted`` marking
        accepted draft rows); rejected rows' pool slots are rolled back
        via :meth:`~.kvcache.IndexChain.pop_slot`, so a fully rejected
        draft leaves page accounting exactly where it started.
        Temperature-0 output is bit-identical with speculation on or
        off."""
        batch = self._active[: self.ecfg.max_slots]
        if not batch:
            return []
        obs = self.obs
        t_trace0 = 0.0
        if obs.enabled:
            # deterministic clock: every event this iteration stamps
            # total_iters, so event steps are machine-independent
            obs.set_step(self.total_iters)
            t_trace0 = obs.now()
        blocks = self._plan_blocks(batch)
        if obs.enabled:
            obs.complete("plan_blocks", "engine", t_trace0,
                         n_streams=len(batch),
                         n_rows=sum(len(b) for b in blocks))
        # Reserve pool slots first — the only fallible part of the step —
        # so OutOfPagesError can roll back cleanly and preempt a victim
        # instead of corrupting half-committed streams.
        slots: List[int] = []
        reserved: List[_Stream] = []
        try:
            for st, rows in zip(batch, blocks):
                for _ in rows:
                    slots.append(st.chain.next_slot())
                    reserved.append(st)
        except OutOfPagesError:
            for st in reversed(reserved):
                st.chain.pop_slot()
            victim = self._pick_victim()
            if victim is None:
                raise
            if obs.enabled:
                obs.instant("preempt", "engine", rid=victim,
                            n_live=len(self._reqs))
            self._preempt(victim)
            return [StepEvent(kind="preempted", rid=victim)]
        t_step0 = time.monotonic()
        events: List[StepEvent] = []
        tokens, q_pos, chains, lens = [], [], [], []
        rows_meta: List[Tuple[Optional[int], int, str]] = []
        spans: List[int] = []          # base row index of each block
        for st, rows in zip(batch, blocks):
            spans.append(len(tokens))
            for j, (tok_in, _, _, is_prompt) in enumerate(rows):
                tokens.append(tok_in)
                q_pos.append(st.q_pos + j)
                chains.append(st.chain)
                # full post-reservation length: row j sees its block's
                # earlier rows through the kv_pos <= q_pos position mask
                # (pool K/V is written before attention per layer), and
                # later rows are hidden by the same mask
                lens.append(st.chain.length)
                # cost attribution: row j's mask exposes the chain minus
                # the block rows after it; prompt rows are chunked
                # prefill work, rows past the committed input are the
                # speculative (draft / extra forced) portion
                if is_prompt:
                    phase = "prefill"
                elif j > 0:
                    phase = "spec_verify"
                else:
                    phase = "decode"
                rows_meta.append((st.rid,
                                  st.chain.length - (len(rows) - 1 - j),
                                  phase))
        logits_np = self._decode(tokens, q_pos, slots, chains, lens,
                                 rows_meta)
        n = len(batch)
        step_dt = time.monotonic() - t_step0
        spec_on = self._drafter is not None
        new_streams: List[_Stream] = []
        finished: List[_Stream] = []
        for i, (st, rows) in enumerate(zip(batch, blocks)):
            req = self._reqs[st.rid]
            base = spans[i]
            # longest accepted prefix: row 0 and forced rows commit
            # unconditionally; a draft row commits iff it equals the
            # argmax of the previous row's verified logits (== what
            # greedy sample_token would have produced sequentially)
            n_acc = 1
            while n_acc < len(rows):
                tok, _, isd, _ = rows[n_acc]
                if isd and tok != int(np.argmax(logits_np[base + n_acc - 1])):
                    break
                n_acc += 1
            if spec_on:
                self.spec_stats["proposed"] += sum(
                    1 for r in rows if r[2])
                self.spec_stats["accepted"] += sum(
                    1 for r in rows[:n_acc] if r[2])
                self.spec_stats["forced_batched"] += sum(
                    1 for r in rows[1:n_acc] if r[1])
                self.spec_stats["tokens"] += sum(
                    1 for r in rows[:n_acc] if not r[3])
                if obs.enabled:
                    n_prop = sum(1 for r in rows if r[2])
                    if n_prop:
                        obs.instant(
                            "spec_verify", "spec", rid=st.rid,
                            track=self._track_of(st), proposed=n_prop,
                            accepted=sum(1 for r in rows[:n_acc] if r[2]),
                            rolled_back=len(rows) - n_acc)
            # roll back rejected rows: pop_slot un-reserves this chain's
            # tail slots (newest first); the pages stay owned by the
            # chain, so the next reservation rewrites them in place
            for _ in range(len(rows) - n_acc):
                st.chain.pop_slot()
            phase = {"plan": "planning", "step": "execution",
                     "conclusion": "conclusion",
                     "serial": "planning"}[st.purpose]
            req.timings[phase] += step_dt / n
            n_prompt_rows = 0
            for j in range(n_acc):
                tok_in, was_forced, was_draft, was_prompt = rows[j]
                if was_prompt:
                    # chunked prefill: the prompt token is now in the
                    # pool — advance the write position silently (no
                    # token event, no generation budget consumed)
                    st.pending_prompt.popleft()
                    st.q_pos += 1
                    n_prompt_rows += 1
                    if not st.pending_prompt:
                        self._finish_chunked_prefill(req, st)
                    continue
                if was_forced:
                    st.forced.popleft()
                st.generated.append(tok_in)
                st.q_pos += 1
                st.n_generated += 1
                req.n_tokens += 1
                if obs.enabled and st.n_generated == 1:
                    obs.instant("first_token", "stream", rid=st.rid,
                                track=self._track_of(st))
                if tok_in == st.stop_id or st.n_generated >= st.max_new:
                    st.finish_after = True
                events.append(StepEvent(
                    kind="token", rid=st.rid, token=tok_in,
                    purpose=st.purpose, tid=st.tid, stage=st.stage,
                    forced=was_forced, drafted=was_draft))
            if n_prompt_rows:
                if obs.enabled:
                    obs.complete(
                        "prefill_chunk", "engine", t_trace0, rid=st.rid,
                        seq=st.chunk_seq, offset=st.q_pos - n_prompt_rows,
                        n_rows=n_prompt_rows, n_prompt=st.n_prompt,
                        n_cached=st.n_cached)
                st.chunk_seq += 1
            if not st.pending_prompt and not st.forced and not st.finish_after:
                sp = req.sampling
                st.next_input = int(sample_token(
                    logits_np[base + n_acc - 1], sp.temperature, req.rng,
                    sp.top_k, sp.top_p))
            if st.finish_after:
                st.done = True
                finished.append(st)
        for st in finished:
            self._active.remove(st)
            if obs.enabled:
                self._obs_stream_end(st)
            req = self._reqs[st.rid]
            self._on_stream_done(req, st, new_streams)
            if self.audit is not None:
                rec = self._audit_stream_end(req, st)
                if rec is not None:
                    events.append(StepEvent(
                        kind="audit", rid=st.rid, purpose="step",
                        tid=st.tid, stage=rec.stage, audit=rec))
        self._active.extend(new_streams)
        # stage-aware priority: streams spawned with decode priority (a
        # critic gating >= 2 branches) move to the front of the active
        # list, which is exactly the decode order under slot
        # over-subscription. Stable sort; no-op when nothing holds
        # priority, so all-"reason" workloads keep the legacy order.
        if any(s.priority for s in new_streams):
            self._active.sort(key=lambda s: not s.priority)
        self.total_iters += 1
        if spec_on:
            self.spec_stats["steps"] += 1
        for st in finished:
            req = self._reqs.get(st.rid)
            if req is not None and req.done:
                result = self._finish(req)
                self._release_request(req)
                del self._reqs[req.rid]
                self._preempt_count.pop(req.rid, None)
                if self.audit is not None:
                    # disposition before the request span closes, so the
                    # trace instant lands inside the open request span
                    arec = self.audit.finish_request(
                        req.rid, completed=True, step=self.total_iters)
                    events.append(StepEvent(kind="audit", rid=req.rid,
                                            audit=arec))
                if obs.enabled:
                    extra = ({"cost": self.cost.request_summary(req.rid)}
                             if self.cost is not None else {})
                    obs.end("request", "request", rid=req.rid,
                            n_tokens=result.n_tokens,
                            critical_path_tokens=result.critical_path_tokens,
                            **extra)
                events.append(StepEvent(kind="done", rid=req.rid,
                                        result=result))
        if obs.enabled:
            if self.cost is not None:
                self.cost.emit(obs)
            obs.counter("kv_pages", {"used": self.alloc.used,
                                     "pinned": self.alloc.pinned_pages,
                                     "free": len(self.alloc.free)})
            obs.complete("step", "engine", t_trace0,
                         n_streams=len(batch), n_rows=len(slots),
                         n_events=len(events))
        return events

    # ---------------------------------------------------- batched decode ---
    def _decode(self, tokens: List[int], q_pos: List[int],
                slots: List[int], chains: List[IndexChain],
                lens: List[int],
                rows_meta: Optional[List[Tuple[Optional[int], int, object]]]
                = None) -> np.ndarray:
        """One batched decode call over ``n <= max_slots`` streams,
        dispatched to the configured attention backend. Handles
        power-of-two bucketing (chain width for dense, page count for
        pallas — the kernel's shapes depend only on the page table
        width), batch-row padding with the out-of-range write-slot
        sentinel, the bucket histograms, the compiled-shape watcher and
        the analytic cost ledger. ``rows_meta`` is the cost attribution
        per row — ``(rid, visible_kv_len, phase)`` where phase is a
        string ("prefill" | "decode" | "spec_verify") or the legacy
        is_spec bool — defaulting to unattributed decode rows over the
        full chain length. With an int8 pool the layer scales flow
        through ``paged_decode`` alongside the pool buffers (donated
        and rebound every call). Returns host logits (n, V)."""
        n = len(tokens)
        obs = self.obs
        t0 = obs.now() if obs.enabled else 0.0
        pad = self.ecfg.max_slots - n
        # power-of-two chain bucketing: short chains stop paying
        # max_chain_len-wide attention (and the cap is enforced for both
        # backends — chains must fit the compiled ladder)
        s_bucket = self._chain_bucket(max(lens))
        self.bucket_hist[s_bucket] = self.bucket_hist.get(s_bucket, 0) + 1
        arr = lambda x, d=np.int32: jnp.asarray(
            np.pad(np.asarray(x, d), [(0, pad)] + [(0, 0)] * (np.asarray(x).ndim - 1)))
        # padding rows must not scatter into the pool: give them the
        # out-of-range sentinel slot (dropped inside the decode step)
        slots_p = np.full((self.ecfg.max_slots,), self.pc.n_slots,
                          np.int32)
        slots_p[:n] = slots
        k_sc, v_sc = ((self.pool["k_scale"], self.pool["v_scale"])
                      if self._quantized else (None, None))
        if self.ecfg.attention_backend == "pallas":
            runs = [ch.page_runs() for ch in chains]
            p_bucket = self._page_bucket(max(r[0].size for r in runs))
            self.page_bucket_hist[p_bucket] = (
                self.page_bucket_hist.get(p_bucket, 0) + 1)
            pt = np.zeros((self.ecfg.max_slots, p_bucket), np.int32)
            pv = np.zeros((self.ecfg.max_slots, p_bucket), np.int32)
            for i, (pgs, cnt) in enumerate(runs):
                pt[i, : pgs.size] = pgs
                pv[i, : pgs.size] = cnt
            # the pallas decode's compiled shape depends on the
            # page-table width, not the chain bucket
            new_shape = self.compiles.note(("decode", "pallas", p_bucket))
            t_c = obs.now() if (obs.enabled and new_shape) else 0.0
            (logits, self.pool["k"], self.pool["v"], self.pool["pos"],
             k_sc, v_sc) = paged_decode(
                self.params, self.pool["k"], self.pool["v"],
                self.pool["pos"], k_sc, v_sc, arr(tokens), arr(q_pos),
                jnp.asarray(slots_p), None, None, self.cfg,
                backend="pallas", page_table=jnp.asarray(pt),
                page_valid=jnp.asarray(pv),
                page_size=self.pc.page_size,
                interpret=self.ecfg.kernel_interpret)
            pages = [r[0].size for r in runs]
        else:
            padded = [ch.padded(s_bucket) for ch in chains]
            new_shape = self.compiles.note(("decode", "dense", s_bucket))
            t_c = obs.now() if (obs.enabled and new_shape) else 0.0
            (logits, self.pool["k"], self.pool["v"], self.pool["pos"],
             k_sc, v_sc) = paged_decode(
                self.params, self.pool["k"], self.pool["v"],
                self.pool["pos"], k_sc, v_sc, arr(tokens), arr(q_pos),
                jnp.asarray(slots_p),
                jnp.asarray(np.pad(np.stack(padded), [(0, pad), (0, 0)])),
                arr(lens), self.cfg, page_size=self.pc.page_size)
            p_bucket = 0
            pages = [len(ch.pages) for ch in chains]
        if self._quantized:
            self.pool["k_scale"], self.pool["v_scale"] = k_sc, v_sc
        out = np.asarray(logits[:n])   # host sync: dur covers the device
        if new_shape and obs.enabled:
            obs.complete(
                "compile", "compile", t_c, kind="decode",
                backend=self.ecfg.attention_backend,
                chain_bucket=s_bucket, page_bucket=p_bucket,
                after_warmup=self.compiles.warmup_step is not None)
        if self.cost is not None:
            if rows_meta is None:
                rows_meta = [(None, ln, "decode") for ln in lens]
            self.cost.note_decode(rows_meta, s_bucket, pages,
                                  self.ecfg.attention_backend)
        if obs.enabled:
            obs.complete("decode", "engine", t0, n_rows=n,
                         bucket=s_bucket,
                         backend=self.ecfg.attention_backend)
        return out

    def _page_bucket(self, n: int) -> int:
        """Smallest power-of-two page-table width covering ``n`` pages,
        floored at the page count of a ``min_chain_bucket``-token chain
        so the compiled ladder mirrors the dense chain buckets."""
        b = max(self.ecfg.min_chain_bucket // self.pc.page_size, 1)
        while b < n:
            b <<= 1
        return b

    # ------------------------------------------------------- preemption ----
    def _pick_victim(self) -> Optional[int]:
        """Youngest live request (highest rid — preempted requests keep
        their original id, so they count as old and get to finish).
        ``None`` when fewer than two requests are live: evicting the only
        request cannot free pages it will not immediately need again."""
        rids = {st.rid for st in self._active}
        if len(rids) < 2:
            return None
        victim = max(rids)
        if self._preempt_count.get(victim, 0) >= self.ecfg.max_preemptions:
            return None
        return victim

    def _preempt(self, rid: int) -> None:
        """Release every chain the victim holds and forget its state; the
        caller re-queues it for re-prefill (cheap when the prompt is
        still radix-cached)."""
        req = self._reqs.pop(rid)
        self._drop_streams(rid)
        self._release_request(req)
        self.preemptions += 1
        self._preempt_count[rid] = self._preempt_count.get(rid, 0) + 1
        if self.audit is not None:
            # verdicts are deferred to the re-run: drop the victim's
            # partial decision records so re-admission (same rid, full
            # re-decode) cannot produce duplicates; no disposition yet
            self.audit.on_preempt(rid)
        if self.obs.enabled:
            self.obs.end("request", "request", rid=rid, reason="preempted")

    def _drop_streams(self, rid: int) -> None:
        for st in [s for s in self._active if s.rid == rid]:
            self._active.remove(st)
            if self.obs.enabled:
                self._obs_stream_end(st, aborted=True)
            st.chain.release()

    # --------------------------------------------------- observability -----
    @staticmethod
    def _track_of(st: _Stream) -> str:
        """Perfetto thread (track) of a stream: ``plan`` / ``t<N>``
        (DAG transition N, 1-based as in the plan text) /
        ``conclusion`` / ``serial``."""
        return f"t{st.tid + 1}" if st.purpose == "step" else st.purpose

    def _obs_stream_begin(self, st: _Stream) -> None:
        req = self._reqs.get(st.rid)
        label = req.labels.get(st.tid, "") if req is not None else ""
        self.obs.begin("stream", "stream", rid=st.rid,
                       track=self._track_of(st), purpose=st.purpose,
                       tid=st.tid, q_pos=st.q_pos, label=label,
                       stage=st.stage)

    def _obs_stream_end(self, st: _Stream, aborted: bool = False) -> None:
        extra = {"aborted": True} if aborted else {}
        self.obs.end("stream", "stream", rid=st.rid,
                     track=self._track_of(st), n_tokens=st.n_generated,
                     **extra)

    # ----------------------------------------------------------- audit -----
    def _audit_evidence(self, req: _Request, tr) -> str:
        """Concatenated predecessor texts of transition ``tr`` — the
        grounding context the verdict extractor checks a critic body
        against. Context-sourced transitions ground on the plan text."""
        parts = []
        for p in tr.pre:
            if p == req.sched.net.ctx_place:
                parts.append(req.plan_text)
            else:
                res = req.step_results.get(self._tid_of_place(req, p))
                if res is not None:
                    parts.append(res[0])
        return " ".join(parts)

    def _audit_stream_end(self, req: _Request,
                          st: _Stream) -> Optional[AuditRecord]:
        """Feed a finished stream to the audit trail. Step streams count
        toward per-stage totals; critic/guardrail streams additionally
        produce a decision record (returned; None otherwise). The body
        the extractor sees excludes the forced ``<Step>`` header."""
        if st.purpose != "step" or req.sched is None:
            return None
        tr = req.sched.net.transition(st.tid)
        body = self.tok.decode(st.generated[st.n_header:])
        return self.audit.on_stream_end(
            req.rid, node=st.tid, stage=tr.stage, body=body,
            evidence=self._audit_evidence(req, tr),
            step=self.total_iters, track=self._track_of(st))

    def dump_audit(self, path: Optional[str] = None) -> str:
        """Write the audit trail as ``medverse-audit/1`` JSONL at
        ``path`` (defaults to ``EngineConfig.audit`` when that is a
        path). Returns the path written."""
        if self.audit is None:
            raise ValueError(
                "auditing is disabled; set EngineConfig.audit")
        if path is None and isinstance(self.ecfg.audit, str):
            path = self.ecfg.audit
        if not path:
            raise ValueError(
                "no audit path: pass one, or set EngineConfig.audit "
                "to a path instead of True")
        return self.audit.dump_jsonl(path)

    def dump_trace(self, path: Optional[str] = None
                   ) -> Tuple[str, str]:
        """Write the recorded trace twice: the native JSONL schema at
        ``path`` (defaults to ``EngineConfig.trace`` when that is a
        path) and the Chrome trace-event export next to it
        (``<path minus .jsonl>.chrome.json``) — load the latter at
        https://ui.perfetto.dev. Returns ``(jsonl_path, chrome_path)``.
        """
        if not self.obs.enabled:
            raise ValueError(
                "tracing is disabled; set EngineConfig.trace")
        if path is None and isinstance(self.ecfg.trace, str):
            path = self.ecfg.trace
        if not path:
            raise ValueError(
                "no trace path: pass one, or set EngineConfig.trace "
                "to a path instead of True")
        self.obs.dump_jsonl(path)
        base = path[: -len(".jsonl")] if path.endswith(".jsonl") else path
        chrome = base + ".chrome.json"
        self.obs.dump_chrome(chrome)
        return path, chrome

    def metrics_registry(self) -> MetricsRegistry:
        """Snapshot the engine's lifetime telemetry into a fresh
        :class:`~repro.obs.metrics.MetricsRegistry` — built on demand
        from the plain-int counters the engine already keeps, so the
        decode hot path pays nothing for it. Use ``.to_prom_text()``
        for Prometheus exposition or ``.snapshot()`` for the JSON dict
        merged into :class:`~repro.serving.metrics.ServingReport`."""
        reg = MetricsRegistry(prefix="medverse_")
        a = self.alloc.stats()
        reg.counter("kv_pages_allocated_total",
                    "lifetime page allocations").inc(a["allocs"])
        reg.counter("kv_pages_freed_total",
                    "lifetime pages returned to the free list").inc(
                        a["frees"])
        reg.counter("kv_page_pins_total",
                    "lifetime radix cache pins taken").inc(a["pins"])
        reg.counter("kv_page_unpins_total",
                    "lifetime radix cache pins dropped").inc(a["unpins"])
        reg.counter("kv_page_reclaims_total",
                    "successful reclaim rounds under page pressure").inc(
                        a["reclaims"])
        reg.gauge("kv_pages_in_use",
                  "pages with a live stream reference").set(a["in_use"])
        reg.gauge("kv_pages_used",
                  "pages off the free list (streams + cache)").set(
                      a["used"])
        reg.gauge("kv_pages_pinned",
                  "pages held only as radix cache").set(a["pinned"])
        reg.gauge("kv_pages_peak_in_use",
                  "high-water pages_in_use").set(a["peak_in_use"])
        reg.gauge("kv_pages_total", "pool size").set(a["n_pages"])
        reg.counter("radix_hits_total",
                    "prefix lookups that matched").inc(self.radix.hits)
        reg.counter("radix_misses_total",
                    "prefix lookups that missed").inc(self.radix.misses)
        reg.counter("radix_inserts_total",
                    "insertions that added a node").inc(self.radix.inserts)
        reg.counter("radix_evictions_total",
                    "LRU leaf evictions").inc(self.radix.evictions)
        reg.counter("decode_steps_total",
                    "batched decode iterations").inc(self.total_iters)
        reg.counter("preemptions_total",
                    "page-pressure evictions").inc(self.preemptions)
        for k, v in self.spec_stats.items():
            reg.counter(f"spec_{k}_total",
                        f"speculative decoding: lifetime {k}").inc(v)
        # bucket histograms: always exported (empty ones with zero
        # counts) over the *configured* ladder, so /metrics scrapes see
        # stable bucket boundaries across runs and restarts
        ladder = self.bucket_ladder()
        h = reg.histogram("decode_chain_bucket", buckets=ladder,
                          help="decode steps per chain bucket width")
        for b in sorted(self.bucket_hist):
            h.observe(b, self.bucket_hist[b])
        page_ladder = sorted({self._page_bucket(-(-s // self.pc.page_size))
                              for s in ladder})
        h = reg.histogram("decode_page_bucket", buckets=page_ladder,
                          help="pallas decode steps per page-table "
                               "width")
        for b in sorted(self.page_bucket_hist):
            h.observe(b, self.page_bucket_hist[b])
        self.compiles.register(reg)
        if self.cost is not None:
            self.cost.register(reg)
        if self.audit is not None:
            c = self.audit.counts()
            reg.counter("audit_records_total",
                        "audit records emitted (decisions + "
                        "dispositions)").inc(c["records"])
            for s in VERDICT_STATUSES:
                reg.counter(f"audit_verdict_{s}_total",
                            f"critic/guardrail decisions with verdict "
                            f"{s}").inc(c[f"verdict_{s}"])
            for d in DISPOSITIONS:
                reg.counter(f"audit_disposition_{d}_total",
                            f"requests closed with disposition "
                            f"{d}").inc(c[d])
        reg.gauge("active_streams",
                  "decode streams currently live").set(len(self._active))
        reg.gauge("live_requests",
                  "requests currently in flight").set(len(self._reqs))
        return reg

    # ------------------------------------------------------------- main ----
    def generate(self, prompts: List[str],
                 plans: Optional[List[Optional[str]]] = None,
                 samplings: Optional[List[Optional[SamplingParams]]] = None
                 ) -> List[GenResult]:
        """Closed-batch wrapper over the step-level API: admit while
        slots are free, step until every request drains. ``plans[i]``
        (optional) teacher-forces request i's plan — per-request version
        of EngineConfig.plan_override; ``samplings[i]`` overrides its
        sampling parameters."""
        waiting: deque = deque(
            (None, p,
             plans[i] if plans else None,
             samplings[i] if samplings else None)
            for i, p in enumerate(prompts))
        spec_of: Dict[int, Tuple] = {}
        order: List[int] = []
        results: Dict[int, GenResult] = {}
        iters0 = self.total_iters
        while waiting or self._reqs:
            # admit requests while slots free (mid-flight, every step)
            while waiting and self.has_capacity():
                rid0, p, plan, sp = waiting[0]
                try:
                    rid = self.add_request(p, plan=plan, sampling=sp,
                                           rid=rid0)
                except OutOfPagesError:
                    if not self._reqs:
                        raise   # nothing to preempt: pool truly too small
                    break       # retry once running requests free pages
                waiting.popleft()
                spec_of[rid] = (p, plan, sp)
                if rid0 is None:
                    order.append(rid)
            for ev in self.step():
                if ev.kind == "done":
                    results[ev.rid] = ev.result
                elif ev.kind == "preempted" and ev.rid in spec_of:
                    # victim re-queued at the front: it is re-admitted as
                    # soon as pages free up, keeping its rid (and seed).
                    # Requests added via add_request() before this call
                    # are not ours to re-queue — their owner re-admits.
                    waiting.appendleft((ev.rid,) + spec_of[ev.rid])
        self.last_iters = self.total_iters - iters0
        return [results[rid] for rid in order]

    def _release_request(self, req: _Request) -> None:
        """Explicit page reclamation: drop every chain the request held
        so ``alloc.used`` returns to its pre-request level. Radix-pinned
        prompt pages persist as reclaimable cache."""
        for _txt, chain, _end in req.step_results.values():
            chain.release()
        if req.ctx_chain is not None:
            req.ctx_chain.release()
        if req.final_chain is not None:
            req.final_chain.release()

    # ------------------------------------------------------- bucketing ----
    def _chain_bucket(self, n: int) -> int:
        """Smallest power-of-two bucket (>= min_chain_bucket) covering a
        chain of length ``n``, capped at max_chain_len. The bounded
        ladder of bucket widths bounds decode recompilations."""
        b = self.ecfg.min_chain_bucket
        while b < n:
            b <<= 1
        b = min(b, self.ecfg.max_chain_len)
        if n > b:
            raise ValueError(
                f"chain length {n} exceeds max_chain_len="
                f"{self.ecfg.max_chain_len}")
        return b

    def bucket_ladder(self) -> List[int]:
        out = []
        b = self.ecfg.min_chain_bucket
        while b < self.ecfg.max_chain_len:
            out.append(b)
            b <<= 1
        out.append(self.ecfg.max_chain_len)
        return out

    def warmup(self, buckets: Optional[List[int]] = None) -> List[int]:
        """Pre-compile the batched decode step for each chain bucket so
        no request pays XLA compilation mid-generation, plus the first
        prefill bucket (``PREFILL_BUCKET``-token prompts — longer
        prompts legitimately compile their wider bucket on first
        arrival). Under the pallas backend the compiled decode shapes
        depend on the page-table width, so each chain bucket warms its
        corresponding page bucket (chains with many partial pages —
        deep joins — may still compile one wider table at runtime; the
        ``CompileWatcher`` counts exactly that as
        ``recompiles_after_warmup``, which CI gates to zero on the
        smoke workload). Returns the warmed bucket widths."""
        obs = self.obs
        buckets = buckets or self.bucket_ladder()
        pg = self.alloc.alloc_page()  # scratch page, freed afterwards
        slot = pg * self.pc.page_size
        n = self.ecfg.max_slots
        backend = self.ecfg.attention_backend
        for s in buckets:
            t_c = obs.now() if obs.enabled else 0.0
            k_sc, v_sc = ((self.pool["k_scale"], self.pool["v_scale"])
                          if self._quantized else (None, None))
            if backend == "pallas":
                pb = self._page_bucket(-(-s // self.pc.page_size))
                new_shape = self.compiles.note(("decode", "pallas", pb))
                pt = np.zeros((n, pb), np.int32)
                pv = np.zeros((n, pb), np.int32)
                pt[:, 0] = pg
                pv[:, 0] = 1
                (_, self.pool["k"], self.pool["v"], self.pool["pos"],
                 k_sc, v_sc) = paged_decode(
                    self.params, self.pool["k"], self.pool["v"],
                    self.pool["pos"], k_sc, v_sc,
                    jnp.zeros((n,), jnp.int32),
                    jnp.zeros((n,), jnp.int32),
                    jnp.full((n,), slot, jnp.int32), None, None,
                    self.cfg, backend="pallas",
                    page_table=jnp.asarray(pt),
                    page_valid=jnp.asarray(pv),
                    page_size=self.pc.page_size,
                    interpret=self.ecfg.kernel_interpret)
            else:
                pb = 0
                new_shape = self.compiles.note(("decode", "dense", s))
                chain = np.zeros((n, s), np.int32)
                chain[:, 0] = slot
                (_, self.pool["k"], self.pool["v"], self.pool["pos"],
                 k_sc, v_sc) = paged_decode(
                    self.params, self.pool["k"], self.pool["v"],
                    self.pool["pos"], k_sc, v_sc,
                    jnp.zeros((n,), jnp.int32), jnp.zeros((n,), jnp.int32),
                    jnp.full((n,), slot, jnp.int32), jnp.asarray(chain),
                    jnp.ones((n,), jnp.int32), self.cfg,
                    page_size=self.pc.page_size)
            if self._quantized:
                self.pool["k_scale"], self.pool["v_scale"] = k_sc, v_sc
            if new_shape and obs.enabled:
                obs.complete("compile", "compile", t_c, kind="decode",
                             backend=backend, chain_bucket=s,
                             page_bucket=pb,
                             after_warmup=self.compiles.warmup_step
                             is not None)
        self.alloc.decref(pg)
        # warm the smallest prefill bucket too (pure forward, no pool
        # write), so short-prompt arrivals mid-run never compile
        if self.compiles.note(("prefill", backend, self.PREFILL_BUCKET)):
            t_c = obs.now() if obs.enabled else 0.0
            prefill_forward(
                self.params,
                jnp.zeros((1, self.PREFILL_BUCKET), jnp.int32),
                jnp.arange(self.PREFILL_BUCKET, dtype=jnp.int32)[None],
                self.cfg, jnp.int32(1), backend=backend,
                interpret=self.ecfg.kernel_interpret)
            if obs.enabled:
                obs.complete("compile", "compile", t_c, kind="prefill",
                             backend=backend,
                             bucket=self.PREFILL_BUCKET,
                             after_warmup=self.compiles.warmup_step
                             is not None)
        self.compiles.finish_warmup(self.total_iters)
        if obs.enabled:
            obs.meta(warmup_step=self.compiles.warmup_step,
                     warmup_buckets=list(buckets))
        return buckets

    def _finish(self, req: _Request) -> GenResult:
        steps = {tid + 1: txt for tid, (txt, _, _) in
                 sorted(req.step_results.items())}
        parts = [req.plan_text]
        parts += [steps[k] for k in sorted(steps)]
        parts.append(req.conclusion_text)
        topo = (req.dag.classify_topology() if req.dag is not None
                else "single_linear_chain")
        # critical-path depth of the GENERATED region (the paper's O(D)):
        # max adaptive end position minus the prompt prefix length
        crit = max(req.max_end - len(req.prompt_ids), 1)
        return GenResult(
            text=" ".join(parts), ok=True, n_tokens=req.n_tokens,
            critical_path_tokens=crit,
            wall_s=time.monotonic() - req.t_start,
            plan_ok=req.plan_ok, topology=topo,
            timings=dict(req.timings),
            step_texts=steps, conclusion=req.conclusion_text,
        )


class SerialEngine:
    """Autoregressive baseline: same model, same paged machinery, one
    linear stream per request (no fork/join, no DAG)."""

    def __init__(self, params, cfg: ModelConfig, tok: Tokenizer,
                 ecfg: Optional[EngineConfig] = None):
        self.inner = MedVerseEngine(params, cfg, tok, ecfg)
        if self.inner.ecfg.prefill_chunk > 0:
            raise ValueError(
                "SerialEngine drives _prefill directly and does not "
                "ingest chunked prompts; use prefill_chunk=0")

    def generate(self, prompts: List[str], max_tokens: Optional[int] = None
                 ) -> List[GenResult]:
        eng = self.inner
        results = []
        for rid, p in enumerate(prompts):
            req = _Request(rid, p, eng.tok.encode(p, bos=True),
                           seed=eng.ecfg.seed,
                           sampling=SamplingParams(
                               temperature=eng.ecfg.temperature),
                           plan=eng.ecfg.plan_override)
            st = eng._prefill(req)
            st.purpose = "serial"
            st.stop_id = EOS
            st.max_new = max_tokens or eng.ecfg.max_serial_tokens
            n = 0
            t_req = time.monotonic()
            while not st.done:
                tok_in = st.forced.popleft() if st.forced else st.next_input
                slot = st.chain.next_slot()
                logits = eng._decode([tok_in], [st.q_pos], [slot],
                                     [st.chain], [st.chain.length],
                                     [(req.rid, st.chain.length, False)])
                st.generated.append(tok_in)
                st.q_pos += 1
                n += 1
                sp = req.sampling
                nxt = int(sample_token(logits[0],
                                       sp.temperature, req.rng,
                                       sp.top_k, sp.top_p))
                if tok_in == EOS or n >= st.max_new:
                    st.done = True
                else:
                    st.next_input = nxt
            st.chain.release()  # reclaim the request's pages
            results.append(GenResult(
                text=eng.tok.decode(st.generated), ok=True, n_tokens=n,
                critical_path_tokens=st.q_pos,
                wall_s=time.monotonic() - t_req, plan_ok=False,
                topology="single_linear_chain",
                timings={"serial": time.monotonic() - t_req}))
        return results
