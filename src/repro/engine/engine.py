"""MedVerse Engine: two-phase hybrid execution with continuous batching
(paper Sec. 4.3).

Phase I  — *Linear planning*: standard AR decode per request until the
``</Plan>`` token; the engine then parses the <Outline> dependencies and
instantiates the Petri net (graph initialization).

Phase II — *Frontier-based graph execution*: at each marking M_k the
enabled-transition frontier F_k (Eq. 1) is spawned as parallel decode
streams. **Fork** streams share the parent context via index-chain copy
(zero device copies); **Join** streams merge predecessor chains with
ordered dedup over pool slots (shared ancestors counted once — the
"flexible radix cache layout, no padding or physical copy" claim).
Adaptive positions: every stream in a frontier starts at the max end
position of all completed work (fork alignment / join-max, Sec. 4.2).

All active streams across all requests and phases decode together in one
batched ``paged_decode`` call per iteration — continuous batching.

Scheduler modes
---------------

* ``async_frontier=False`` (paper default): frontier-synchronized. The
  marking only advances when the whole frontier F_k has finished; every
  stream of F_{k+1} starts at the global join-max position.
* ``async_frontier=True``: per-transition marking advance. Each firing
  immediately spawns whichever successors just became enabled
  (``PetriScheduler.ready``), so short branches stop gating long ones.
  Spawn positions use the join-max over the transition's *own*
  predecessors — on DAGs where every join covers its frontier (diamond,
  fan-out) this is the same position the synchronized path uses, so
  temperature-0 output text is identical; on mixed-depth DAGs the engine
  finishes in strictly fewer decode iterations.
* ``radix_cache=True``: cross-request prefix reuse. Prefill consults the
  radix tree before allocating (cache hits adopt existing pool slots) and
  inserts the prompt afterwards; cached pages are pinned in the
  allocator (``PageAllocator.pin``) and evicted LRU under page pressure.
* chain bucketing: every decode step pads chains to the smallest
  power-of-two bucket (>= ``min_chain_bucket``, capped at
  ``max_chain_len``) covering the batch, instead of always paying
  ``max_chain_len``-wide attention; ``warmup()`` pre-compiles the bucket
  ladder so no request hits XLA compilation mid-generation.

Page lifetime: ``generate`` releases every chain a request held when it
finishes, so ``PageAllocator.used`` returns to its pre-request level;
only radix-pinned prompt pages persist, as reclaimable cache.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.dag import CycleError, ReasoningDAG
from ..core.petri import ColoredToken, PetriNet, PetriScheduler
from ..core.plan import PlanParseError, parse_plan
from ..data.tokenizer import EOS, Tokenizer
from ..models.config import ModelConfig
from .kvcache import IndexChain, PageAllocator, PoolConfig, init_pool
from .paged_model import (paged_decode, prefill_forward, prefix_pool_write,
                          supports_paged)
from .radix import RadixTree
from .sampling import sample_token


@dataclasses.dataclass
class EngineConfig:
    max_slots: int = 8
    page_size: int = 16
    n_pages: int = 4096
    max_chain_len: int = 640
    min_chain_bucket: int = 64     # smallest power-of-two decode bucket
    max_plan_tokens: int = 256
    max_step_tokens: int = 64
    max_conclusion_tokens: int = 96
    max_serial_tokens: int = 512
    temperature: float = 0.0
    # False: frontier-synchronized (paper default). True: per-transition
    # marking advance — successors spawn as soon as their own
    # predecessors fire (see module docstring, "Scheduler modes").
    async_frontier: bool = False
    radix_cache: bool = True       # cross-request prompt-prefix reuse
    seed: int = 0
    # Teacher-forced plan injection: skip LLM planning and force this
    # plan text (deterministic execution; also the Table-5 "Direct Petri
    # Net" ablation hook and the debugging surface).
    plan_override: Optional[str] = None


@dataclasses.dataclass
class GenResult:
    text: str
    ok: bool
    n_tokens: int                 # generated tokens (all streams)
    critical_path_tokens: int     # O(D) depth the paper's latency tracks
    wall_s: float
    plan_ok: bool
    topology: str
    timings: Dict[str, float]
    step_texts: Dict[int, str] = dataclasses.field(default_factory=dict)
    conclusion: str = ""


class _Stream:
    __slots__ = ("chain", "q_pos", "forced", "next_input", "generated",
                 "purpose", "stop_id", "max_new", "done", "finish_after",
                 "n_generated", "rid", "tid")

    def __init__(self, chain: IndexChain, q_pos: int, purpose: str,
                 rid: int, tid: int = -1, stop_id: int = EOS,
                 max_new: int = 64):
        self.chain = chain
        self.q_pos = q_pos
        self.forced: deque = deque()
        self.next_input: Optional[int] = None
        self.generated: List[int] = []
        self.purpose = purpose   # "plan" | "step" | "conclusion" | "serial"
        self.rid = rid
        self.tid = tid
        self.stop_id = stop_id
        self.max_new = max_new
        self.done = False
        self.finish_after = False
        self.n_generated = 0


class _Request:
    def __init__(self, rid: int, prompt_ids: List[int]):
        self.rid = rid
        self.prompt_ids = prompt_ids
        self.state = "planning"
        self.plan = None
        self.dag: Optional[ReasoningDAG] = None
        self.sched: Optional[PetriScheduler] = None
        self.labels: Dict[int, str] = {}
        self.ctx_chain: Optional[IndexChain] = None
        self.final_chain: Optional[IndexChain] = None
        self.ctx_end = 0
        self.max_end = 0
        self.step_results: Dict[int, Tuple[str, IndexChain, int]] = {}
        self.pending_frontier: List[int] = []
        self.plan_text = ""
        self.conclusion_text = ""
        self.plan_ok = False
        self.t_start = 0.0
        self.timings = {"planning": 0.0, "execution": 0.0,
                        "conclusion": 0.0, "fork_join": 0.0,
                        "schedule_parse": 0.0}
        self.n_tokens = 0
        self.done = False


class MedVerseEngine:
    def __init__(self, params, cfg: ModelConfig, tok: Tokenizer,
                 ecfg: Optional[EngineConfig] = None):
        assert supports_paged(cfg), (
            f"{cfg.name}: engine paged path requires attention layers "
            "(SSM/MLA archs use models.decode_step; see DESIGN.md §4)")
        self.params = params
        self.cfg = cfg
        self.tok = tok
        self.ecfg = ecfg or EngineConfig()
        pc = PoolConfig(
            n_layers=cfg.n_layers, n_pages=self.ecfg.n_pages,
            page_size=self.ecfg.page_size, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, dtype=cfg.dtype,
        )
        self.pc = pc
        self.pool = init_pool(pc)
        self.alloc = PageAllocator(pc)
        self.radix = RadixTree(page_size=pc.page_size,
                               on_pin=self.alloc.pin,
                               on_unpin=self.alloc.unpin)
        # under page pressure, reclaim radix-pinned cache pages (LRU)
        self.alloc.reclaim_cb = self.radix.evict_one
        self.last_iters = 0                  # decode iterations, last generate()
        self.bucket_hist: Dict[int, int] = {}  # chain bucket -> decode steps
        self.rng = np.random.default_rng(self.ecfg.seed)
        self.id_plan_end = tok.token_id("</Plan>")
        self.id_step_end = tok.token_id("</Step>")
        self.id_conc_end = tok.token_id("</Conclusion>")
        self.id_exec = tok.token_id("<Execution>")
        self.id_conc = tok.token_id("<Conclusion>")

    # ------------------------------------------------------------ prefill --
    PREFILL_BUCKET = 64

    def _prefill(self, req: _Request, plan_override=None) -> _Stream:
        ids = req.prompt_ids
        n = len(ids)
        chain = IndexChain.fresh(self.alloc)
        cached = np.zeros((0,), np.int32)
        path: List = []
        if self.ecfg.radix_cache:
            # cross-request prefix reuse: adopt cached pool slots instead
            # of allocating; always recompute >= 1 token for the logits
            cached, path = self.radix.match_prefix(ids)
            cached = cached[: n - 1]
            chain.adopt(cached)
        m = int(cached.size)
        new_slots = chain.reserve(n - m)
        # bucket the prompt length so one compilation serves many prompts
        bucket = -(-n // self.PREFILL_BUCKET) * self.PREFILL_BUCKET
        ids_p = np.zeros((bucket,), np.int32)
        ids_p[:n] = ids
        pos_p = np.arange(bucket, dtype=np.int32)
        logits, ks, vs = prefill_forward(
            self.params, jnp.asarray(ids_p)[None],
            jnp.asarray(pos_p)[None], self.cfg, jnp.int32(n))
        # write only positions [m, n): the cached prefix already holds
        # identical K/V; prefix and padding rows get the out-of-range
        # sentinel slot and are dropped device-side
        wslots = np.full((bucket,), self.pc.n_slots, np.int32)
        wslots[m:n] = new_slots
        self.pool["k"], self.pool["v"], self.pool["pos"] = prefix_pool_write(
            self.pool["k"], self.pool["v"], self.pool["pos"],
            ks, vs, jnp.asarray(wslots), jnp.asarray(pos_p))
        if self.ecfg.radix_cache:
            self.radix.insert(ids, chain.idx[:n])
            # pages are pinned via the allocator; lookup refs can go
            self.radix.release(path)
        st = _Stream(chain, q_pos=n, purpose="plan", rid=req.rid,
                     stop_id=self.id_plan_end,
                     max_new=self.ecfg.max_plan_tokens)
        plan = (plan_override if plan_override is not None
                else self.ecfg.plan_override)
        if plan is not None:
            forced = self.tok.encode(plan)
            st.forced.extend(forced)
            st.max_new = len(forced) + 2
        st.next_input = int(sample_token(
            np.asarray(logits), self.ecfg.temperature, self.rng))
        return st

    # --------------------------------------------------------- fork/join ---
    def _start_pos(self, req: _Request, t) -> int:
        """Join-max adaptive position over t's own predecessors (the
        async per-transition advance); the sync path instead starts every
        frontier stream at the global ``req.max_end``."""
        ends = []
        for p in t.pre:
            if p == req.sched.net.ctx_place:
                ends.append(req.ctx_end)
            else:
                ends.append(req.step_results[self._tid_of_place(req, p)][2])
        return max(ends)

    def _spawn_transition(self, req: _Request, t, start_pos: int) -> _Stream:
        tf = time.monotonic()
        if len(t.pre) == 1:
            src = (req.ctx_chain if t.pre[0] == req.sched.net.ctx_place
                   else req.step_results[self._tid_of_place(req, t.pre[0])][1])
            chain = src.fork()
        else:
            chains = [req.step_results[self._tid_of_place(req, p)][1]
                      for p in t.pre]
            chain = self._dedup_join(chains)
        req.timings["fork_join"] += time.monotonic() - tf
        header = self.tok.encode(
            f"<Step> Transient Step {t.tid + 1}: {req.labels.get(t.tid, '')}")
        st = _Stream(chain, q_pos=start_pos, purpose="step",
                     rid=req.rid, tid=t.tid, stop_id=self.id_step_end,
                     max_new=self.ecfg.max_step_tokens + len(header))
        st.forced.extend(header)
        return st

    def _spawn_ready(self, req: _Request) -> List[_Stream]:
        """Spawn every enabled-and-unclaimed transition. Sync mode calls
        this only at frontier barriers (whole-frontier claim at the
        global join-max position); async mode calls it after every
        individual firing (per-transition join-max)."""
        t0 = time.monotonic()
        fj_before = req.timings["fork_join"]
        ready = req.sched.ready()
        if not ready:
            return []
        req.sched.history.append([t.tid for t in ready])
        streams = []
        for t in ready:
            start = (self._start_pos(req, t) if self.ecfg.async_frontier
                     else req.max_end)
            req.sched.claim(t)
            streams.append(self._spawn_transition(req, t, start))
        req.pending_frontier.extend(s.tid for s in streams)
        fj_delta = req.timings["fork_join"] - fj_before
        req.timings["schedule_parse"] += time.monotonic() - t0 - fj_delta
        return streams

    def _tid_of_place(self, req: _Request, place: int) -> int:
        # PetriNet.from_dag: output place of transition t is t + 1
        return place - 1

    def _dedup_join(self, chains: List[IndexChain]) -> IndexChain:
        """Ordered dedup over pool slots: shared ancestors once, branch
        suffixes in order. Zero device copies."""
        alloc = chains[0].alloc
        out = IndexChain(alloc)
        seen = dict()
        parts = []
        pages = set()
        for ch in chains:
            arr = ch.idx[:ch.length]
            mask = np.fromiter((int(s) not in seen for s in arr), bool,
                               count=len(arr))
            for s in arr[mask]:
                seen[int(s)] = True
            parts.append(arr[mask])
            pages |= ch.pages
        out.idx = (np.concatenate(parts).astype(np.int32)
                   if parts else np.zeros((0,), np.int32))
        out.length = int(out.idx.shape[0])
        out.pages = pages
        for pg in pages:
            alloc.incref(pg)
        return out

    def _spawn_conclusion(self, req: _Request) -> _Stream:
        tf = time.monotonic()
        chains = [req.ctx_chain] + [req.step_results[t][1]
                                    for t in sorted(req.step_results)]
        chain = self._dedup_join(chains)
        req.timings["fork_join"] += time.monotonic() - tf
        st = _Stream(chain, q_pos=req.max_end, purpose="conclusion",
                     rid=req.rid, stop_id=self.id_conc_end,
                     max_new=self.ecfg.max_conclusion_tokens)
        st.forced.append(self.id_conc)
        return st

    # ------------------------------------------------------- stream done ---
    def _on_stream_done(self, req: _Request, st: _Stream,
                        new_streams: List[_Stream]) -> None:
        text = self.tok.decode(st.generated)
        if st.purpose == "plan":
            req.plan_text = text
            t0 = time.monotonic()
            try:
                plan = parse_plan(text, lenient=True)
                dag = plan.to_dag()
                req.plan = plan
                req.dag = dag
                req.labels = plan.labels()
                net = PetriNet.from_dag(dag, req.labels)
                req.sched = PetriScheduler(
                    net, ColoredToken(history=text, kv_ref=st.chain))
                req.plan_ok = True
                req.state = "executing"
                req.ctx_chain = st.chain
                req.ctx_end = st.q_pos
                req.max_end = st.q_pos
            except (PlanParseError, CycleError):
                # graceful fallback: no valid plan -> go straight to a
                # conclusion over the linear context (serial behaviour)
                req.plan_ok = False
                req.state = "concluding"
                req.ctx_chain = st.chain
                req.ctx_end = st.q_pos
                req.max_end = st.q_pos
                req.step_results = {}
            req.timings["schedule_parse"] += time.monotonic() - t0
            if req.state == "executing":
                new_streams.extend(self._spawn_ready(req))
            else:
                new_streams.append(self._spawn_conclusion(req))
        elif st.purpose == "step":
            # fire the transition: output token carries (text, chain)
            tr = req.sched.net.transition(st.tid)
            req.sched.fire(tr, ColoredToken(history=text, kv_ref=st.chain))
            req.step_results[st.tid] = (text, st.chain, st.q_pos)
            req.max_end = max(req.max_end, st.q_pos)
            req.pending_frontier.remove(st.tid)
            # sync: advance the marking only at the frontier barrier;
            # async: every firing may enable successors immediately
            if self.ecfg.async_frontier or not req.pending_frontier:
                nxt = self._spawn_ready(req)
                new_streams.extend(nxt)
                if not nxt and not req.pending_frontier:
                    req.state = "concluding"
                    new_streams.append(self._spawn_conclusion(req))
        elif st.purpose in ("conclusion", "serial"):
            req.conclusion_text = text
            req.final_chain = st.chain
            req.done = True

    # ------------------------------------------------------------- main ----
    def generate(self, prompts: List[str],
                 plans: Optional[List[Optional[str]]] = None
                 ) -> List[GenResult]:
        """``plans[i]`` (optional) teacher-forces request i's plan —
        per-request version of EngineConfig.plan_override."""
        reqs = [_Request(rid, self.tok.encode(p, bos=True))
                for rid, p in enumerate(prompts)]
        plan_of = {r.rid: (plans[i] if plans else None)
                   for i, r in enumerate(reqs)}
        waiting = deque(reqs)
        active: List[_Stream] = []
        t_global = time.monotonic()
        for r in reqs:
            r.t_start = t_global
        results: Dict[int, GenResult] = {}
        n_iters = 0
        while waiting or active:
            # admit requests while slots free
            while waiting and len(active) < self.ecfg.max_slots:
                req = waiting.popleft()
                active.append(self._prefill(req, plan_of.get(req.rid)))
            batch = active[: self.ecfg.max_slots]
            t_step0 = time.monotonic()
            tokens, q_pos, slots, lens = [], [], [], []
            for st in batch:
                tok_in = (st.forced.popleft() if st.forced
                          else st.next_input)
                slot = st.chain.next_slot()
                tokens.append(tok_in)
                q_pos.append(st.q_pos)
                slots.append(slot)
                lens.append(st.chain.length)
                st.generated.append(tok_in)
                st.q_pos += 1
                st.n_generated += 1
                if tok_in == st.stop_id or st.n_generated >= st.max_new:
                    st.finish_after = True
            # power-of-two chain bucketing: short chains stop paying
            # max_chain_len-wide attention
            s_bucket = self._chain_bucket(max(lens))
            self.bucket_hist[s_bucket] = self.bucket_hist.get(s_bucket, 0) + 1
            chains = [st.chain.padded(s_bucket) for st in batch]
            n = len(batch)
            pad = self.ecfg.max_slots - n
            arr = lambda x, d=np.int32: jnp.asarray(
                np.pad(np.asarray(x, d), [(0, pad)] + [(0, 0)] * (np.asarray(x).ndim - 1)))
            # padding rows must not scatter into the pool: give them the
            # out-of-range sentinel slot (dropped inside paged_decode)
            slots_p = np.full((self.ecfg.max_slots,), self.pc.n_slots,
                              np.int32)
            slots_p[:n] = slots
            logits, self.pool["k"], self.pool["v"], self.pool["pos"] = paged_decode(
                self.params, self.pool["k"], self.pool["v"], self.pool["pos"],
                arr(tokens), arr(q_pos), jnp.asarray(slots_p),
                jnp.asarray(np.pad(np.stack(chains), [(0, pad), (0, 0)])),
                arr(lens), self.cfg)
            logits_np = np.asarray(logits[:n])
            step_dt = time.monotonic() - t_step0
            new_streams: List[_Stream] = []
            finished: List[_Stream] = []
            for i, st in enumerate(batch):
                req = reqs[st.rid]
                phase = {"plan": "planning", "step": "execution",
                         "conclusion": "conclusion",
                         "serial": "planning"}[st.purpose]
                req.timings[phase] += step_dt / n
                req.n_tokens += 1
                if not st.forced and not st.finish_after:
                    st.next_input = int(sample_token(
                        logits_np[i], self.ecfg.temperature, self.rng))
                if st.finish_after:
                    st.done = True
                    finished.append(st)
            for st in finished:
                active.remove(st)
                self._on_stream_done(reqs[st.rid], st, new_streams)
            active.extend(new_streams)
            n_iters += 1
            for req in reqs:
                if req.done and req.rid not in results:
                    results[req.rid] = self._finish(req, t_global)
                    self._release_request(req)
        self.last_iters = n_iters
        return [results[r.rid] for r in reqs]

    def _release_request(self, req: _Request) -> None:
        """Explicit page reclamation: drop every chain the request held
        so ``alloc.used`` returns to its pre-request level. Radix-pinned
        prompt pages persist as reclaimable cache."""
        for _txt, chain, _end in req.step_results.values():
            chain.release()
        if req.ctx_chain is not None:
            req.ctx_chain.release()
        if req.final_chain is not None:
            req.final_chain.release()

    # ------------------------------------------------------- bucketing ----
    def _chain_bucket(self, n: int) -> int:
        """Smallest power-of-two bucket (>= min_chain_bucket) covering a
        chain of length ``n``, capped at max_chain_len. The bounded
        ladder of bucket widths bounds decode recompilations."""
        b = self.ecfg.min_chain_bucket
        while b < n:
            b <<= 1
        b = min(b, self.ecfg.max_chain_len)
        if n > b:
            raise ValueError(
                f"chain length {n} exceeds max_chain_len="
                f"{self.ecfg.max_chain_len}")
        return b

    def bucket_ladder(self) -> List[int]:
        out = []
        b = self.ecfg.min_chain_bucket
        while b < self.ecfg.max_chain_len:
            out.append(b)
            b <<= 1
        out.append(self.ecfg.max_chain_len)
        return out

    def warmup(self, buckets: Optional[List[int]] = None) -> List[int]:
        """Pre-compile the batched decode step for each chain bucket so
        no request pays XLA compilation mid-generation. Returns the
        warmed bucket widths."""
        buckets = buckets or self.bucket_ladder()
        pg = self.alloc.alloc_page()  # scratch page, freed afterwards
        slot = pg * self.pc.page_size
        n = self.ecfg.max_slots
        for s in buckets:
            chain = np.zeros((n, s), np.int32)
            chain[:, 0] = slot
            _, self.pool["k"], self.pool["v"], self.pool["pos"] = paged_decode(
                self.params, self.pool["k"], self.pool["v"], self.pool["pos"],
                jnp.zeros((n,), jnp.int32), jnp.zeros((n,), jnp.int32),
                jnp.full((n,), slot, jnp.int32), jnp.asarray(chain),
                jnp.ones((n,), jnp.int32), self.cfg)
        self.alloc.decref(pg)
        return buckets

    def _finish(self, req: _Request, t_global: float) -> GenResult:
        steps = {tid + 1: txt for tid, (txt, _, _) in
                 sorted(req.step_results.items())}
        parts = [req.plan_text]
        parts += [steps[k] for k in sorted(steps)]
        parts.append(req.conclusion_text)
        topo = (req.dag.classify_topology() if req.dag is not None
                else "single_linear_chain")
        # critical-path depth of the GENERATED region (the paper's O(D)):
        # max adaptive end position minus the prompt prefix length
        crit = max(req.max_end - len(req.prompt_ids), 1)
        return GenResult(
            text=" ".join(parts), ok=True, n_tokens=req.n_tokens,
            critical_path_tokens=crit,
            wall_s=time.monotonic() - t_global,
            plan_ok=req.plan_ok, topology=topo,
            timings=dict(req.timings),
            step_texts=steps, conclusion=req.conclusion_text,
        )


class SerialEngine:
    """Autoregressive baseline: same model, same paged machinery, one
    linear stream per request (no fork/join, no DAG)."""

    def __init__(self, params, cfg: ModelConfig, tok: Tokenizer,
                 ecfg: Optional[EngineConfig] = None):
        self.inner = MedVerseEngine(params, cfg, tok, ecfg)

    def generate(self, prompts: List[str], max_tokens: Optional[int] = None
                 ) -> List[GenResult]:
        eng = self.inner
        results = []
        t0 = time.monotonic()
        for rid, p in enumerate(prompts):
            req = _Request(rid, eng.tok.encode(p, bos=True))
            st = eng._prefill(req)
            st.purpose = "serial"
            st.stop_id = EOS
            st.max_new = max_tokens or eng.ecfg.max_serial_tokens
            n = 0
            t_req = time.monotonic()
            while not st.done:
                tok_in = st.forced.popleft() if st.forced else st.next_input
                slot = st.chain.next_slot()
                s_bucket = eng._chain_bucket(st.chain.length)
                eng.bucket_hist[s_bucket] = (
                    eng.bucket_hist.get(s_bucket, 0) + 1)
                logits, eng.pool["k"], eng.pool["v"], eng.pool["pos"] = paged_decode(
                    eng.params, eng.pool["k"], eng.pool["v"], eng.pool["pos"],
                    jnp.asarray(np.pad([tok_in], (0, eng.ecfg.max_slots - 1))),
                    jnp.asarray(np.pad([st.q_pos], (0, eng.ecfg.max_slots - 1))),
                    jnp.asarray(np.pad([slot], (0, eng.ecfg.max_slots - 1),
                                       constant_values=eng.pc.n_slots)),
                    jnp.asarray(np.pad(
                        st.chain.padded(s_bucket)[None],
                        [(0, eng.ecfg.max_slots - 1), (0, 0)])),
                    jnp.asarray(np.pad([st.chain.length],
                                       (0, eng.ecfg.max_slots - 1))),
                    eng.cfg)
                st.generated.append(tok_in)
                st.q_pos += 1
                n += 1
                nxt = int(sample_token(np.asarray(logits[0]),
                                       eng.ecfg.temperature, eng.rng))
                if tok_in == EOS or n >= st.max_new:
                    st.done = True
                else:
                    st.next_input = nxt
            st.chain.release()  # reclaim the request's pages
            results.append(GenResult(
                text=eng.tok.decode(st.generated), ok=True, n_tokens=n,
                critical_path_tokens=st.q_pos,
                wall_s=time.monotonic() - t_req, plan_ok=False,
                topology="single_linear_chain",
                timings={"serial": time.monotonic() - t_req}))
        return results
