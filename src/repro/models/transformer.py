"""Transformer assembly: stages of scanned super-blocks, full forward
(train/prefill) and single-token decode, covering every assigned family.

Layer stacking
--------------
``compute_stages`` groups the config's layer pattern into *stages*: a
stage is a (unit, n_repeat, uses_moe) triple whose parameters are stacked
along a leading axis and executed with ``lax.scan`` (+ optional remat).
Heterogeneous interleavings (gemma3 5 local : 1 global, recurrentgemma
r,r,attn) become multi-layer units; deepseek-v3's 3 dense-FFN first
layers become their own stage before the MoE stage.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .attention import (
    TopoBatch,
    attention_decode,
    attention_forward,
    cross_attention_forward,
    init_attention,
    init_mla,
    mla_decode,
    mla_forward,
)
from .config import ATTN, LOCAL_ATTN, RGLRU, RWKV6, ModelConfig
from .layers import (
    apply_norm,
    apply_mlp,
    embed_tokens,
    init_embedding,
    init_learned_pos,
    init_mlp,
    init_norm,
    learned_pos,
    maybe_shard,
    unembed,
)
from .moe import init_moe, moe_ffn
from .rglru import (
    init_rglru,
    rglru_decode,
    rglru_forward,
    rglru_init_state,
)
from .rwkv import (
    init_rwkv_cm,
    init_rwkv_tm,
    rwkv_cm_decode,
    rwkv_cm_forward,
    rwkv_init_state,
    rwkv_tm_decode,
    rwkv_tm_forward,
)
from . import meshctx


@dataclasses.dataclass(frozen=True)
class Stage:
    unit: Tuple[str, ...]
    n: int
    moe: bool
    start_layer: int


def compute_stages(cfg: ModelConfig) -> List[Stage]:
    stages: List[Stage] = []
    li = 0
    unit = tuple(cfg.pattern_unit)
    n = cfg.n_repeat
    if cfg.moe is not None and cfg.moe.first_dense_layers:
        fd = cfg.moe.first_dense_layers
        assert unit == (ATTN,), "first_dense_layers requires plain attn unit"
        stages.append(Stage(unit=unit, n=fd, moe=False, start_layer=0))
        li = fd
        n = n - fd
    if n > 0:
        stages.append(Stage(unit=unit, n=n, moe=cfg.moe is not None,
                            start_layer=li))
        li += n * len(unit)
    if cfg.tail:
        stages.append(
            Stage(unit=tuple(cfg.tail), n=1, moe=cfg.moe is not None,
                  start_layer=li)
        )
    return stages


# ----------------------------------------------------------------- init ----
def _init_mixer(key, cfg: ModelConfig, kind: str) -> dict:
    if kind in (ATTN, LOCAL_ATTN):
        if cfg.mla is not None:
            return init_mla(key, cfg)
        return init_attention(key, cfg)
    if kind == RGLRU:
        return init_rglru(key, cfg)
    if kind == RWKV6:
        return init_rwkv_tm(key, cfg)
    raise ValueError(kind)


def _init_ffn(key, cfg: ModelConfig, moe: bool, kind: str) -> dict:
    if moe:
        return init_moe(key, cfg)
    if kind == RWKV6:
        return init_rwkv_cm(key, cfg)
    d_ff = cfg.d_ff
    if cfg.moe is not None and cfg.moe.d_ff_dense:
        d_ff = cfg.moe.d_ff_dense
    return init_mlp(key, cfg.d_model, d_ff, cfg.mlp_activation,
                    jnp.dtype(cfg.dtype))


def _init_unit(key, cfg: ModelConfig, unit: Tuple[str, ...], moe: bool) -> dict:
    """Params for one super-block instance: dict u0..u{len-1}."""
    p = {}
    keys = jax.random.split(key, len(unit))
    for i, kind in enumerate(unit):
        k1, k2, k3 = jax.random.split(keys[i], 3)
        sub = {
            "norm1": init_norm(cfg.d_model, cfg.norm_type),
            "mixer": _init_mixer(k1, cfg, kind),
            "norm2": init_norm(cfg.d_model, cfg.norm_type),
            "ffn": _init_ffn(k2, cfg, moe, kind),
        }
        if cfg.encoder is not None and kind in (ATTN, LOCAL_ATTN):
            sub["cross_norm"] = init_norm(cfg.d_model, cfg.norm_type)
            sub["cross"] = init_attention(k3, cfg, cross=True)
        p[f"u{i}"] = sub
    return p


def _stack(trees: List[Any]) -> Any:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def init_stage(key, cfg: ModelConfig, stage: Stage) -> dict:
    keys = jax.random.split(key, stage.n)
    return _stack([_init_unit(k, cfg, stage.unit, stage.moe) for k in keys])


def init_encoder(key, cfg: ModelConfig) -> dict:
    enc = cfg.encoder
    keys = jax.random.split(key, enc.n_layers + 2)
    layers = []
    for i in range(enc.n_layers):
        k1, k2 = jax.random.split(keys[i])
        layers.append({
            "norm1": init_norm(cfg.d_model, cfg.norm_type),
            "attn": init_attention(k1, cfg, cross=True),  # full heads, bidir
            "norm2": init_norm(cfg.d_model, cfg.norm_type),
            "ffn": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_activation,
                            jnp.dtype(cfg.dtype)),
        })
    return {
        "layers": _stack(layers),
        "pos": init_learned_pos(keys[-2], enc.n_ctx, cfg.d_model,
                                jnp.dtype(cfg.dtype)),
        "final_norm": init_norm(cfg.d_model, cfg.norm_type),
    }


def init_params(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model,
                                jnp.dtype(cfg.dtype)),
        "final_norm": init_norm(cfg.d_model, cfg.norm_type),
    }
    stages = compute_stages(cfg)
    stage_keys = jax.random.split(ks[1], len(stages))
    params["stages"] = [init_stage(k, cfg, s) for k, s in zip(stage_keys, stages)]
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(ks[2], cfg.vocab_size, cfg.d_model,
                                           jnp.dtype(cfg.dtype))["table"].T
    if cfg.pos_embedding == "learned":
        params["pos"] = init_learned_pos(ks[3], cfg.max_seq_len, cfg.d_model,
                                         jnp.dtype(cfg.dtype))
    if cfg.encoder is not None:
        params["encoder"] = init_encoder(ks[4], cfg)
    if cfg.vision is not None and cfg.vision.embed_dim:
        # projector stub: maps frontend embeddings into d_model
        from .layers import init_linear
        params["vision_proj"] = init_linear(ks[5], cfg.vision.embed_dim,
                                            cfg.d_model, jnp.dtype(cfg.dtype))
    if cfg.mtp_depth > 0:
        k1, k2, k3 = jax.random.split(ks[6], 3)
        from .layers import init_linear
        params["mtp"] = {
            "proj": init_linear(k1, 2 * cfg.d_model, cfg.d_model,
                                jnp.dtype(cfg.dtype)),
            "block": _init_unit(k2, cfg, (ATTN,), moe=False),
            "norm": init_norm(cfg.d_model, cfg.norm_type),
        }
    return params


# -------------------------------------------------------------- forward ----
def _apply_unit_fwd(unit_params: dict, x: jnp.ndarray, cfg: ModelConfig,
                    unit: Tuple[str, ...], moe: bool, topo: TopoBatch,
                    enc_out: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    aux = jnp.zeros((), jnp.float32)
    daxes = meshctx.data_axes()
    for i, kind in enumerate(unit):
        p = unit_params[f"u{i}"]
        h = apply_norm(p["norm1"], x, cfg.norm_type, cfg.norm_eps)
        if kind in (ATTN, LOCAL_ATTN):
            if cfg.mla is not None:
                mix = mla_forward(p["mixer"], h, topo, cfg, kind)
            else:
                mix = attention_forward(p["mixer"], h, topo, cfg, kind)
        elif kind == RGLRU:
            mix = rglru_forward(p["mixer"], h, cfg)
        else:  # RWKV6
            mix = rwkv_tm_forward(p["mixer"], h, cfg)
        x = x + mix
        if "cross" in p and enc_out is not None:
            hc = apply_norm(p["cross_norm"], x, cfg.norm_type, cfg.norm_eps)
            x = x + cross_attention_forward(p["cross"], hc, enc_out, cfg)
        h2 = apply_norm(p["norm2"], x, cfg.norm_type, cfg.norm_eps)
        if moe:
            y, a = moe_ffn(p["ffn"], h2, cfg)
            aux = aux + a
        elif kind == RWKV6:
            y = rwkv_cm_forward(p["ffn"], h2)
        else:
            y = apply_mlp(p["ffn"], h2, cfg.mlp_activation)
        x = x + y
        x = maybe_shard(x, P(daxes, None, None))
    return x, aux


def encoder_forward(params: dict, audio_embeds: jnp.ndarray,
                    cfg: ModelConfig) -> jnp.ndarray:
    """Whisper-style bidirectional encoder over stubbed frame embeddings."""
    enc = params["encoder"]
    n_ctx = audio_embeds.shape[1]
    x = audio_embeds + learned_pos(enc["pos"], jnp.arange(n_ctx))

    def body(x, lp):
        h = apply_norm(lp["norm1"], x, cfg.norm_type, cfg.norm_eps)
        x = x + cross_attention_forward(lp["attn"], h, h, cfg)  # bidir self
        h2 = apply_norm(lp["norm2"], x, cfg.norm_type, cfg.norm_eps)
        x = x + apply_mlp(lp["ffn"], h2, cfg.mlp_activation)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, enc["layers"])
    return apply_norm(enc["final_norm"], x, cfg.norm_type, cfg.norm_eps)


def forward(
    params: dict,
    tokens: jnp.ndarray,                 # (B, S)
    topo: TopoBatch,
    cfg: ModelConfig,
    image_embeds: Optional[jnp.ndarray] = None,  # (B, n_img, D_vis)
    audio_embeds: Optional[jnp.ndarray] = None,  # (B, n_ctx, D)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits (B,S,V), aux_loss scalar)."""
    daxes = meshctx.data_axes()
    x = embed_tokens(params["embed"], tokens)
    if image_embeds is not None:
        img = image_embeds
        if "vision_proj" in params:
            img = img @ params["vision_proj"]
        n_img = img.shape[1]
        x = jnp.concatenate([img.astype(x.dtype), x[:, n_img:]], axis=1)
    if cfg.pos_embedding == "learned":
        x = x + learned_pos(params["pos"], topo.pos_id)
    x = maybe_shard(x, P(daxes, None, None))
    enc_out = None
    if cfg.encoder is not None and audio_embeds is not None:
        enc_out = encoder_forward(params, audio_embeds, cfg)

    aux_total = jnp.zeros((), jnp.float32)
    for stage, sp in zip(compute_stages(cfg), params["stages"]):
        def body(carry, unit_params, _stage=stage):
            x, aux = carry
            x, a = _apply_unit_fwd(unit_params, x, cfg, _stage.unit,
                                   _stage.moe, topo, enc_out)
            return (x, aux + a), None

        if cfg.remat:
            body = jax.checkpoint(body)
        if cfg.scan_layers and stage.n > 1:
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), sp)
        else:
            for i in range(stage.n):
                unit_p = jax.tree_util.tree_map(lambda a, i=i: a[i], sp)
                (x, aux_total), _ = body((x, aux_total), unit_p)

    x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    head = params["lm_head"] if "lm_head" in params else params["embed"]["table"].T
    logits = unembed(head, x, cfg.logit_softcap)
    logits = maybe_shard(logits, P(daxes, None, "model"))
    return logits, aux_total


def mtp_forward(params: dict, tokens: jnp.ndarray, h_final: jnp.ndarray,
                topo: TopoBatch, cfg: ModelConfig) -> jnp.ndarray:
    """DeepSeek-V3 multi-token prediction head (depth 1): combine the
    trunk state at t with the embedding of token t+1 to predict t+2.
    Returns logits (B, S-1, V) aligned to predict tokens[:, 2:]."""
    mtp = params["mtp"]
    emb_next = embed_tokens(params["embed"], tokens[:, 1:])
    h = jnp.concatenate([h_final[:, :-1], emb_next], axis=-1) @ mtp["proj"]
    topo_shift = TopoBatch(
        seg_id=topo.seg_id[:, 1:], layer_id=topo.layer_id[:, 1:],
        pos_id=topo.pos_id[:, 1:], seg_visible=topo.seg_visible,
    )
    h, _ = _apply_unit_fwd(mtp["block"], h, cfg, (ATTN,), False, topo_shift, None)
    h = apply_norm(mtp["norm"], h, cfg.norm_type, cfg.norm_eps)
    head = params["lm_head"] if "lm_head" in params else params["embed"]["table"].T
    return unembed(head, h, cfg.logit_softcap)


def forward_with_hidden(params, tokens, topo, cfg, **kw):
    """forward() but also returns final hidden states (for MTP)."""
    # small duplication kept simple: rerun final norm input by re-tracing
    # is wasteful; instead forward() is inlined here when MTP is on.
    daxes = meshctx.data_axes()
    x = embed_tokens(params["embed"], tokens)
    x = maybe_shard(x, P(daxes, None, None))
    aux_total = jnp.zeros((), jnp.float32)
    for stage, sp in zip(compute_stages(cfg), params["stages"]):
        def body(carry, unit_params, _stage=stage):
            x, aux = carry
            x, a = _apply_unit_fwd(unit_params, x, cfg, _stage.unit,
                                   _stage.moe, topo, None)
            return (x, aux + a), None
        if cfg.remat:
            body = jax.checkpoint(body)
        if cfg.scan_layers and stage.n > 1:
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), sp)
        else:
            for i in range(stage.n):
                unit_p = jax.tree_util.tree_map(lambda a, i=i: a[i], sp)
                (x, aux_total), _ = body((x, aux_total), unit_p)
    h_final = x
    x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    head = params["lm_head"] if "lm_head" in params else params["embed"]["table"].T
    logits = unembed(head, x, cfg.logit_softcap)
    logits = maybe_shard(logits, P(daxes, None, "model"))
    return logits, aux_total, h_final


# ---------------------------------------------------------------- decode ---
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> dict:
    """Dense decode cache for serve_step (dry-run + simple serving)."""
    dt = jnp.dtype(dtype or cfg.dtype)
    hd, nkv = cfg.resolved_head_dim, cfg.n_kv_heads
    cache: Dict[str, Any] = {
        "kv_pos": jnp.zeros((batch, max_len), jnp.int32),
        "kv_valid": jnp.zeros((batch, max_len), bool),
        "stages": [],
    }
    for stage in compute_stages(cfg):
        per_unit = {}
        for i, kind in enumerate(stage.unit):
            if kind in (ATTN, LOCAL_ATTN):
                if cfg.mla is not None:
                    m = cfg.mla
                    c = {
                        "c_kv": jnp.zeros((stage.n, batch, max_len,
                                           m.kv_lora_rank), dt),
                        "k_rope": jnp.zeros((stage.n, batch, max_len,
                                             m.qk_rope_head_dim), dt),
                    }
                elif kind == LOCAL_ATTN:
                    # window-sized ring buffer: O(window) decode state,
                    # what makes gemma3/recurrentgemma long_500k-eligible
                    buf = min(cfg.sliding_window, max_len)
                    c = {
                        "k": jnp.zeros((stage.n, batch, buf, nkv, hd), dt),
                        "v": jnp.zeros((stage.n, batch, buf, nkv, hd), dt),
                        "pos": jnp.zeros((stage.n, batch, buf), jnp.int32),
                        "valid": jnp.zeros((stage.n, batch, buf), bool),
                    }
                else:
                    c = {
                        "k": jnp.zeros((stage.n, batch, max_len, nkv, hd), dt),
                        "v": jnp.zeros((stage.n, batch, max_len, nkv, hd), dt),
                    }
                if cfg.encoder is not None:
                    c["cross_k"] = jnp.zeros(
                        (stage.n, batch, cfg.encoder.n_ctx, cfg.n_heads, hd), dt)
                    c["cross_v"] = jnp.zeros(
                        (stage.n, batch, cfg.encoder.n_ctx, cfg.n_heads, hd), dt)
            elif kind == RGLRU:
                st = rglru_init_state(batch, cfg, dt)
                c = jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (stage.n,) + a.shape), st)
                # local attn window cache lives in its own unit slot
            else:  # RWKV6
                st = rwkv_init_state(batch, cfg, dt)
                c = jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (stage.n,) + a.shape), st)
            per_unit[f"u{i}"] = c
        cache["stages"].append(per_unit)
    return cache


def _apply_unit_decode(unit_params, unit_cache, x_t, cfg, unit, moe,
                       write_index, visible, kv_pos, q_pos):
    new_cache = {}
    for i, kind in enumerate(unit):
        p = unit_params[f"u{i}"]
        c = unit_cache[f"u{i}"]
        h = apply_norm(p["norm1"], x_t, cfg.norm_type, cfg.norm_eps)
        if kind in (ATTN, LOCAL_ATTN):
            if cfg.mla is not None:
                mix, c2 = _mla_decode_dense(p["mixer"], h, c, write_index,
                                            visible, kv_pos, q_pos, cfg)
            elif kind == LOCAL_ATTN:
                mix, c2 = _local_attn_decode(p["mixer"], h, c, write_index,
                                             q_pos, cfg)
            else:
                mix, c2 = _attn_decode_dense(p["mixer"], h, c, write_index,
                                             visible, kv_pos, q_pos, cfg, kind)
            if "cross" in p and "cross_k" in c:
                hc = apply_norm(p["cross_norm"], x_t + mix, cfg.norm_type,
                                cfg.norm_eps)
                mix = mix + _cross_decode(p["cross"], hc, c, cfg)
                c2["cross_k"], c2["cross_v"] = c["cross_k"], c["cross_v"]
        elif kind == RGLRU:
            mix, c2 = rglru_decode(p["mixer"], h, c, cfg)
        else:
            mix, c2 = rwkv_tm_decode(
                p["mixer"], h, {"wkv": c["wkv"], "shift": c["shift"]}, cfg)
            c2 = {**c2, "cm_shift": c["cm_shift"]}
        x_t = x_t + mix
        h2 = apply_norm(p["norm2"], x_t, cfg.norm_type, cfg.norm_eps)
        if moe:
            y, _ = moe_ffn(p["ffn"], h2, cfg)
        elif kind == RWKV6:
            y, new_shift = rwkv_cm_decode(p["ffn"], h2, c["cm_shift"])
            c2["cm_shift"] = new_shift
        else:
            y = apply_mlp(p["ffn"], h2, cfg.mlp_activation)
        x_t = x_t + y
        new_cache[f"u{i}"] = c2
    return x_t, new_cache


def _local_attn_decode(p, h, c, write_index, q_pos, cfg):
    """Sliding-window decode against a ring buffer of size `window`."""
    import math as _m
    b = h.shape[0]
    hd, nh, nkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    g = nh // nkv
    q = (h @ p["wq"]).reshape(b, 1, nh, hd)
    k_t = (h @ p["wk"]).reshape(b, 1, nkv, hd)
    v_t = (h @ p["wv"]).reshape(b, 1, nkv, hd)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm", cfg.norm_eps)
        k_t = apply_norm(p["k_norm"], k_t, "rmsnorm", cfg.norm_eps)
    if cfg.pos_embedding == "rope":
        from .layers import apply_rope
        q = apply_rope(q, q_pos[:, None], cfg.rope_theta)
        k_t = apply_rope(k_t, q_pos[:, None], cfg.rope_theta)
    buf = c["k"].shape[2] if c["k"].ndim == 5 else c["k"].shape[1]
    # cache inside a unit (after scan slicing) is (B, buf, nkv, hd)
    slot = jnp.mod(write_index, buf)
    k = jax.lax.dynamic_update_slice_in_dim(c["k"], k_t.astype(c["k"].dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(c["v"], v_t.astype(c["v"].dtype), slot, axis=1)
    pos = c["pos"].at[:, slot].set(q_pos)
    valid = c["valid"].at[:, slot].set(True)
    diff = q_pos[:, None] - pos
    visible = valid & (diff >= 0) & (diff < cfg.sliding_window)
    qg = q.reshape(b, 1, nkv, g, hd)
    sc = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                    k.astype(jnp.float32)) / _m.sqrt(hd)
    if cfg.attn_logit_softcap > 0:
        sc = jnp.tanh(sc / cfg.attn_logit_softcap) * cfg.attn_logit_softcap
    from ..core.masks import NEG_INF
    sc = sc + jnp.where(visible[:, None, None, None, :], 0.0, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    out = out.reshape(b, 1, nh * hd).astype(h.dtype)
    return out @ p["wo"], {"k": k, "v": v, "pos": pos, "valid": valid}


def _attn_decode_dense(p, h, c, write_index, visible, kv_pos, q_pos, cfg, kind):
    from .attention import attention_decode  # local to avoid cycle
    # attention_decode handles rope/qk-norm/window; it takes kv_pos/kv_valid
    out, new = attention_decode(
        p, h, {"k": c["k"], "v": c["v"]},
        write_index,
        kv_pos[:, : c["k"].shape[1]],
        visible[:, : c["k"].shape[1]],
        q_pos, cfg, kind,
    )
    return out, {"k": new["k"], "v": new["v"]}


def _mla_decode_dense(p, h, c, write_index, visible, kv_pos, q_pos, cfg):
    out, new = mla_decode(
        p, h, {"c_kv": c["c_kv"], "k_rope": c["k_rope"]},
        write_index, kv_pos, visible, q_pos, cfg,
    )
    return out, {"c_kv": new["c_kv"], "k_rope": new["k_rope"]}


def _cross_decode(p, h, c, cfg):
    b = h.shape[0]
    hd, nh = cfg.resolved_head_dim, cfg.n_heads
    q = (h @ p["wq"]).reshape(b, 1, nh, hd)
    import math as _m
    sc = jnp.einsum("bqnh,bsnh->bnqs", q.astype(jnp.float32),
                    c["cross_k"].astype(jnp.float32)) / _m.sqrt(hd)
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bnqs,bsnh->bqnh", w, c["cross_v"].astype(jnp.float32))
    return out.reshape(b, 1, nh * hd).astype(h.dtype) @ p["wo"]


def decode_step(
    params: dict,
    cache: dict,
    token_t: jnp.ndarray,      # (B,) int32
    write_index: jnp.ndarray,  # scalar int32
    q_pos: jnp.ndarray,        # (B,) adaptive positions
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, dict]:
    """One decode step for all active streams. Returns (logits (B,V), cache)."""
    b = token_t.shape[0]
    x = embed_tokens(params["embed"], token_t)[:, None, :]
    if cfg.pos_embedding == "learned":
        x = x + learned_pos(params["pos"], q_pos)[:, None, :]
    kv_pos = cache["kv_pos"].at[:, write_index].set(q_pos)
    kv_valid = cache["kv_valid"].at[:, write_index].set(True)
    visible = kv_valid & (kv_pos <= q_pos[:, None])

    new_stage_caches = []
    for stage, sp, sc in zip(compute_stages(cfg), params["stages"],
                             cache["stages"]):
        if cfg.scan_layers and stage.n > 1:
            def body(x_t, xs, _stage=stage):
                unit_p, unit_c = xs
                x_t, new_c = _apply_unit_decode(
                    unit_p, unit_c, x_t, cfg, _stage.unit, _stage.moe,
                    write_index, visible, kv_pos, q_pos)
                return x_t, new_c
            x, new_c = jax.lax.scan(body, x, (sp, sc))
        else:
            new_cs = []
            for i in range(stage.n):
                unit_p = jax.tree_util.tree_map(lambda a, i=i: a[i], sp)
                unit_c = jax.tree_util.tree_map(lambda a, i=i: a[i], sc)
                x, nc = _apply_unit_decode(
                    unit_p, unit_c, x, cfg, stage.unit, stage.moe,
                    write_index, visible, kv_pos, q_pos)
                new_cs.append(nc)
            new_c = _stack(new_cs)
        new_stage_caches.append(new_c)

    x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    head = params["lm_head"] if "lm_head" in params else params["embed"]["table"].T
    logits = unembed(head, x, cfg.logit_softcap)[:, 0]
    return logits, {"kv_pos": kv_pos, "kv_valid": kv_valid,
                    "stages": new_stage_caches}


def prefill_cross_kv(params: dict, cache: dict, enc_out: jnp.ndarray,
                     cfg: ModelConfig) -> dict:
    """Precompute whisper cross-attention K/V from encoder output."""
    hd, nh = cfg.resolved_head_dim, cfg.n_heads
    b, t, _ = enc_out.shape
    new_stages = []
    for stage, sp, sc in zip(compute_stages(cfg), params["stages"],
                             cache["stages"]):
        sc = dict(sc)
        for i, kind in enumerate(stage.unit):
            if kind in (ATTN, LOCAL_ATTN) and "cross_k" in sc[f"u{i}"]:
                def per_layer(pp):
                    k = (enc_out @ pp[f"u{i}"]["cross"]["wk"]).reshape(b, t, nh, hd)
                    v = (enc_out @ pp[f"u{i}"]["cross"]["wv"]).reshape(b, t, nh, hd)
                    return k, v

                ks, vs = jax.vmap(
                    lambda pp: per_layer(pp), in_axes=(0,)
                )(sp)
                unit_c = dict(sc[f"u{i}"])
                unit_c["cross_k"] = ks.astype(unit_c["cross_k"].dtype)
                unit_c["cross_v"] = vs.astype(unit_c["cross_v"].dtype)
                sc[f"u{i}"] = unit_c
        new_stages.append(sc)
    return {**cache, "stages": new_stages}
