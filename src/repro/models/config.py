"""Model configuration system.

One ``ModelConfig`` describes every assigned architecture family:
dense GQA decoders (llama3.2 / starcoder2 / qwen3), local:global mixes
(gemma3), hybrid attention+RG-LRU (recurrentgemma), enc-dec (whisper),
VLM token interleave (phi-3-vision), attention-free RWKV6, and MoE
(dbrx, deepseek-v3 with MLA + shared expert + MTP).

Layer stacking is expressed as a repeating ``pattern_unit`` plus a
``tail`` so the transformer can ``lax.scan`` over homogeneous
super-blocks (compile-time control at 61-64 layers) while preserving
heterogeneous interleavings like gemma3's 5 local : 1 global.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# layer kinds
ATTN = "attn"            # global self-attention
LOCAL_ATTN = "local_attn"  # sliding-window self-attention
RGLRU = "rglru"          # RecurrentGemma RG-LRU recurrent block
RWKV6 = "rwkv6"          # RWKV-6 "Finch" time-mix block
LAYER_KINDS = (ATTN, LOCAL_ATTN, RGLRU, RWKV6)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int = 0
    first_dense_layers: int = 0     # deepseek-v3: first 3 layers dense
    d_ff_dense: int = 0             # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    router_scoring: str = "softmax"  # dbrx: softmax; deepseek-v3: sigmoid


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention (arXiv:2412.19437)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU block (arXiv:2402.19427)."""

    lru_width: int = 0          # defaults to d_model
    conv1d_width: int = 4
    n_heads: int = 0            # block-diagonal gating heads
    c_constant: float = 8.0


@dataclasses.dataclass(frozen=True)
class RWKV6Config:
    """RWKV-6 'Finch' data-dependent decay (arXiv:2404.05892)."""

    head_dim: int = 64
    decay_lora: int = 64        # low-rank data-dependent decay proj
    mix_lora: int = 32          # low-rank token-shift mixers


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style bidirectional encoder consumed via cross-attention.

    The conv/mel frontend is STUBBED per the assignment: ``input_specs``
    provides precomputed frame embeddings (B, n_ctx, d_model)."""

    n_layers: int = 32
    n_ctx: int = 1500           # whisper-large-v3 encoder positions


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    """VLM stub frontend: precomputed patch embeddings are interleaved as
    prefix tokens (source places in the Petri net)."""

    n_image_tokens: int = 256
    embed_dim: int = 0          # defaults to d_model (projector output)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str              # dense | moe | ssm | hybrid | audio | vlm
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    head_dim: int = 0           # defaults to d_model // n_heads
    pattern_unit: Tuple[str, ...] = (ATTN,)
    tail: Tuple[str, ...] = ()
    sliding_window: int = 4096
    qk_norm: bool = False
    pos_embedding: str = "rope"   # rope | learned | none
    rope_theta: float = 10_000.0
    mlp_activation: str = "swiglu"  # swiglu | gelu
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float = 0.0      # gemma-style final logit soft-capping
    attn_logit_softcap: float = 0.0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rglru: Optional[RGLRUConfig] = None
    rwkv: Optional[RWKV6Config] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionConfig] = None
    mtp_depth: int = 0              # deepseek-v3 multi-token prediction
    # MedVerse: whether attention layers consume DAG topology metadata.
    medverse_attention: bool = True
    # strict ancestor mask (beyond-paper consistency variant) vs Eq. 3
    ancestor_mask: bool = False
    # execution details
    scan_layers: bool = True
    remat: bool = True
    attn_impl: str = "naive"        # naive | chunked (see §Perf)
    attn_chunk_kv: int = 1024       # kv chunk for attn_impl="chunked"
    dtype: str = "float32"          # param/activation dtype
    max_seq_len: int = 8192
    # long_500k eligibility: sub-quadratic decode state (SSM/hybrid/
    # sliding-window). Pure full-attention archs keep this False and the
    # skip is recorded in DESIGN.md §4.
    long_context_ok: bool = False

    # ------------------------------------------------------------------
    def __post_init__(self):
        n_pattern = self.n_repeat * len(self.pattern_unit) + len(self.tail)
        if n_pattern != self.n_layers:
            raise ValueError(
                f"{self.name}: pattern does not tile n_layers="
                f"{self.n_layers}: unit={self.pattern_unit} x "
                f"{self.n_repeat} + tail={self.tail}"
            )
        for k in tuple(self.pattern_unit) + tuple(self.tail):
            if k not in LAYER_KINDS:
                raise ValueError(f"unknown layer kind {k}")

    @property
    def n_repeat(self) -> int:
        unit = len(self.pattern_unit)
        return (self.n_layers - len(self.tail)) // unit

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self.pattern_unit) * self.n_repeat + tuple(self.tail)

    @property
    def uses_attention(self) -> bool:
        return any(k in (ATTN, LOCAL_ATTN) for k in self.layer_kinds)

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None

    def moe_layer_index(self, li: int) -> bool:
        """True if layer ``li`` uses the MoE FFN (vs dense)."""
        return self.moe is not None and li >= self.moe.first_dense_layers

    # -- parameter counting (for roofline MODEL_FLOPS = 6*N*D) ----------
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        nh, nkv = self.n_heads, self.n_kv_heads
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d
        for li, kind in enumerate(self.layer_kinds):
            total += 2 * d  # two norms
            if kind in (ATTN, LOCAL_ATTN):
                if self.mla is not None:
                    m = self.mla
                    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
                    total += d * m.q_lora_rank + m.q_lora_rank * nh * qk_hd
                    total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    total += m.kv_lora_rank * nh * (m.qk_nope_head_dim + m.v_head_dim)
                    total += nh * m.v_head_dim * d
                else:
                    total += d * nh * hd + 2 * d * nkv * hd + nh * hd * d
                if self.encoder is not None:  # cross-attention too
                    total += d * nh * hd + 2 * d * nkv * hd + nh * hd * d
            elif kind == RGLRU:
                w = (self.rglru.lru_width or d)
                total += 2 * d * w + w * d + self.rglru.conv1d_width * w + 2 * w
            elif kind == RWKV6:
                total += 4 * d * d + d * d  # r,k,v,g,o
                total += 2 * d * self.rwkv.decay_lora
            # FFN
            if self.moe is not None and self.moe_layer_index(li):
                me = self.moe
                e_params = me.n_experts * 3 * d * me.d_ff_expert
                if active_only:
                    e_params = me.top_k * 3 * d * me.d_ff_expert
                total += e_params + me.n_shared_experts * 3 * d * me.d_ff_shared
                total += d * me.n_experts  # router
            else:
                ff = (
                    self.moe.d_ff_dense
                    if (self.moe is not None and self.moe.d_ff_dense)
                    else self.d_ff
                )
                mult = 3 if self.mlp_activation == "swiglu" else 2
                total += mult * d * ff
        if self.encoder is not None:
            e = self.encoder
            per_layer = 2 * d + 2 * (d * nh * hd + 2 * d * nkv * hd) // 2
            enc = e.n_layers * (
                2 * d + (d * nh * hd + 2 * d * nh * hd + nh * hd * d)
                + (3 if self.mlp_activation == "swiglu" else 2) * d * self.d_ff
            )
            total += enc
        return total



def validate_config(cfg: ModelConfig) -> None:
    assert cfg.d_model % cfg.n_heads == 0 or cfg.head_dim, cfg.name
    assert cfg.n_heads % max(cfg.n_kv_heads, 1) == 0, (
        f"{cfg.name}: n_heads must be divisible by n_kv_heads"
    )
    if RGLRU in cfg.layer_kinds:
        assert cfg.rglru is not None
    if RWKV6 in cfg.layer_kinds:
        assert cfg.rwkv is not None
    if cfg.moe is not None:
        assert cfg.moe.top_k <= cfg.moe.n_experts
