"""Mixture-of-Experts FFN with explicit expert parallelism.

Two routing flavors cover the assigned MoE archs:
  * DBRX (hf:databricks/dbrx-base): 16 experts, top-4, softmax router.
  * DeepSeek-V3 (arXiv:2412.19437): 256 routed experts top-8 with sigmoid
    scoring + in-group renormalization, plus 1 shared expert (computed
    densely outside the dispatch).

Dispatch is the TPU-friendly *entry scatter* scheme: each (token, k)
entry gets a (local expert, slot) coordinate via a masked cumsum; tokens
are scattered into a static (E_local, capacity, D) buffer, run through a
batched einsum (MXU-shaped grouped matmul), and scattered back. No
(N, E, C) one-hot tensor is ever materialized.

Under a mesh (set via ``meshctx``) the dispatch runs inside ``shard_map``
with experts sharded over the ``model`` axis and a final ``psum`` to
combine per-shard partial outputs — the explicit collective schedule the
roofline analysis reads. Without a mesh the same local function runs with
E_local = E (CPU tests).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig, MoEConfig
from .layers import init_linear
from . import meshctx


def init_moe(key, cfg: ModelConfig) -> dict:
    me: MoEConfig = cfg.moe
    d, f = cfg.d_model, me.d_ff_expert
    ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.dtype)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    p = {
        "router": init_linear(ks[0], d, me.n_experts, dt),
        "w_in": (jax.random.normal(ks[1], (me.n_experts, d, f)) * s_in).astype(dt),
        "w_gate": (jax.random.normal(ks[2], (me.n_experts, d, f)) * s_in).astype(dt),
        "w_out": (jax.random.normal(ks[3], (me.n_experts, f, d)) * s_out).astype(dt),
    }
    if me.n_shared_experts:
        f_sh = me.d_ff_shared or me.n_shared_experts * f
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_in": init_linear(kk[0], d, f_sh, dt),
            "w_gate": init_linear(kk[1], d, f_sh, dt),
            "w_out": init_linear(kk[2], f_sh, d, dt, scale=1.0 / math.sqrt(f_sh)),
        }
    return p


def _route(x_flat: jnp.ndarray, router_w: jnp.ndarray, me: MoEConfig
           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (weights (N,k), ids (N,k), aux_loss scalar)."""
    logits = (x_flat.astype(jnp.float32)) @ router_w.astype(jnp.float32)
    if me.router_scoring == "sigmoid":      # deepseek-v3
        scores = jax.nn.sigmoid(logits)
        w, ids = jax.lax.top_k(scores, me.top_k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    else:                                    # dbrx softmax router
        probs = jax.nn.softmax(logits, axis=-1)
        w, ids = jax.lax.top_k(probs, me.top_k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style): E * sum_i f_i * P_i
    e = me.n_experts
    f_frac = jnp.zeros((e,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f_frac = f_frac / jnp.maximum(ids.size, 1)
    p_mean = probs.mean(axis=0)
    aux = e * jnp.sum(f_frac * p_mean) * me.router_aux_coef
    return w, ids, aux


def _dispatch_compute_local(
    x_flat: jnp.ndarray,     # (N, D)
    ids: jnp.ndarray,        # (N, k) global expert ids
    weights: jnp.ndarray,    # (N, k)
    w_in: jnp.ndarray,       # (E_local, D, F)
    w_gate: jnp.ndarray,
    w_out: jnp.ndarray,      # (E_local, F, D)
    expert_offset: jnp.ndarray,  # scalar: first global expert id on shard
    capacity: int,
) -> jnp.ndarray:
    """Scatter -> grouped einsum -> gather, local experts only."""
    n, d = x_flat.shape
    e_l, _, f = w_in.shape
    k = ids.shape[1]
    ids_f = ids.reshape(-1)
    w_f = weights.reshape(-1).astype(jnp.float32)
    local = (ids_f >= expert_offset) & (ids_f < expert_offset + e_l)
    lid = jnp.where(local, ids_f - expert_offset, 0)
    onehot = jax.nn.one_hot(jnp.where(local, lid, e_l), e_l + 1,
                            dtype=jnp.int32)[:, :e_l]          # (N*k, E_l)
    slot = (jnp.cumsum(onehot, axis=0) - 1)                    # running count
    slot = jnp.take_along_axis(slot, lid[:, None], axis=1)[:, 0]
    keep = local & (slot < capacity)
    flat_idx = jnp.where(keep, lid * capacity + slot, e_l * capacity)
    x_rep = jnp.repeat(x_flat, k, axis=0)                      # (N*k, D)
    buf = jnp.zeros((e_l * capacity + 1, d), x_flat.dtype)
    buf = buf.at[flat_idx].add(jnp.where(keep[:, None], x_rep, 0))
    buf = buf[: e_l * capacity].reshape(e_l, capacity, d)
    h = jnp.einsum("ecd,edf->ecf", buf, w_in)
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, w_out)
    y_flat = y.reshape(e_l * capacity, d)
    y_entries = jnp.take(y_flat, jnp.minimum(flat_idx, e_l * capacity - 1), axis=0)
    y_entries = jnp.where(keep[:, None], y_entries, 0.0)
    y_entries = y_entries.astype(jnp.float32) * w_f[:, None]
    return y_entries.reshape(n, k, d).sum(axis=1).astype(x_flat.dtype)


def moe_ffn(p: dict, x: jnp.ndarray, cfg: ModelConfig
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MoE FFN. x: (B, S, D). Returns (y, router_aux_loss)."""
    me: MoEConfig = cfg.moe
    b, s, d = x.shape
    x_flat = x.reshape(b * s, d)
    weights, ids, aux = _route(x_flat, p["router"], me)

    mesh = meshctx.get_mesh()
    model_axis = meshctx.model_axis()
    ep = (mesh.shape[model_axis] if mesh is not None and
          model_axis in mesh.axis_names else 1)
    if me.n_experts % max(ep, 1) != 0:
        ep = 1  # fall back to replicated experts
    n_tokens = b * s
    if mesh is not None and ep > 1:
        daxes = meshctx.data_axes()
        dsize = 1
        for a in daxes:
            dsize *= mesh.shape[a]
        n_local = max(n_tokens // dsize, 1)
        capacity = max(
            int(math.ceil(n_local * me.top_k / me.n_experts * me.capacity_factor)),
            4,
        )
        e_l = me.n_experts // ep

        def shard_fn(x_l, ids_l, w_l, w_in_l, w_gate_l, w_out_l):
            off = jax.lax.axis_index(model_axis) * e_l
            y_partial = _dispatch_compute_local(
                x_l, ids_l, w_l, w_in_l, w_gate_l, w_out_l, off, capacity
            )
            return jax.lax.psum(y_partial, model_axis)

        y_flat = jax.shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(
                P(daxes, None), P(daxes, None), P(daxes, None),
                P(model_axis, None, None), P(model_axis, None, None),
                P(model_axis, None, None),
            ),
            out_specs=P(daxes, None),
            check_vma=False,
        )(x_flat, ids, weights, p["w_in"], p["w_gate"], p["w_out"])
    else:
        capacity = max(
            int(math.ceil(n_tokens * me.top_k / me.n_experts * me.capacity_factor)),
            4,
        )
        y_flat = _dispatch_compute_local(
            x_flat, ids, weights, p["w_in"], p["w_gate"], p["w_out"],
            jnp.int32(0), capacity,
        )

    if me.n_shared_experts and "shared" in p:
        sh = p["shared"]
        h = x_flat @ sh["w_in"]
        g = x_flat @ sh["w_gate"]
        y_flat = y_flat + (jax.nn.silu(g) * h) @ sh["w_out"]
    return y_flat.reshape(b, s, d), aux


def moe_ref(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Dense oracle: every expert computed for every token, combined by
    router weights (no capacity drops). Used by tests to validate the
    dispatch path on small shapes (capacity_factor high enough)."""
    me = cfg.moe
    b, s, d = x.shape
    x_flat = x.reshape(b * s, d)
    weights, ids, _ = _route(x_flat, p["router"], me)
    h = jnp.einsum("nd,edf->nef", x_flat, p["w_in"])
    g = jnp.einsum("nd,edf->nef", x_flat, p["w_gate"])
    y_all = jnp.einsum("nef,efd->ned", jax.nn.silu(g) * h, p["w_out"])
    mask = jax.nn.one_hot(ids, me.n_experts, dtype=jnp.float32)  # (N,k,E)
    comb = jnp.einsum("nke,nk->ne", mask, weights.astype(jnp.float32))
    y = jnp.einsum("ned,ne->nd", y_all.astype(jnp.float32), comb)
    if me.n_shared_experts and "shared" in p:
        sh = p["shared"]
        y = y + ((jax.nn.silu(x_flat @ sh["w_gate"]) * (x_flat @ sh["w_in"]))
                 @ sh["w_out"]).astype(jnp.float32)
    return y.reshape(b, s, d).astype(x.dtype)
