"""Shared layer primitives: norms, RoPE (with *adaptive position ids*),
MLPs, embeddings, and sharding-constraint helpers.

Everything is functional: ``init_*(key, ...) -> params`` and pure apply
functions. Params are plain nested dicts of jnp arrays so the launch
layer can attach PartitionSpecs by path name.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# --------------------------------------------------------------- sharding --
def maybe_shard(x: jnp.ndarray, spec: Optional[P]) -> jnp.ndarray:
    """Apply a sharding constraint iff we are under a non-trivial mesh.

    Outside a mesh (CPU unit tests) this is a no-op, so model code can
    annotate unconditionally.
    """
    if spec is None:
        return x
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty or mesh.size <= 1:
            return x
        # only constrain if every named axis exists on the mesh AND the
        # constrained dim divides by the axis size — an indivisible
        # constraint (e.g. 4 attention heads over a 16-way model axis)
        # forces XLA into pad/reshard all-reduce churn (measured: 239 GB
        # of all-reduce per device on gemma3 prefill_32k — see
        # EXPERIMENTS.md §Perf iteration H2).
        clean_axes = []
        for dim, axis in zip(x.shape, tuple(spec)):
            if axis is None:
                clean_axes.append(None)
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            size = 1
            ok = True
            for a in axes:
                if a not in mesh.axis_names:
                    ok = False
                    break
                size *= mesh.shape[a]
            clean_axes.append(axis if ok and dim % size == 0 else None)
        if all(a is None for a in clean_axes):
            return x
        return jax.lax.with_sharding_constraint(x, P(*clean_axes))
    except Exception:
        return x


def act_spec(*axes) -> P:
    return P(*axes)


# ------------------------------------------------------------------- norms --
def init_norm(d: int, norm_type: str = "rmsnorm") -> dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p: dict, x: jnp.ndarray, norm_type: str = "rmsnorm",
               eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if norm_type == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(dt)


# -------------------------------------------------------------------- RoPE --
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, pos_id: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding driven by explicit (possibly *adaptive*) positions.

    x: (..., S, H, D); pos_id: broadcastable to (..., S) int32. MedVerse's
    adaptive position indices (Sec. 4.2) enter attention exactly here:
    fork-aligned siblings share angles, joins resume from the max.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    angles = pos_id[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]               # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------- MLP --
def init_mlp(key, d_model: int, d_ff: int, activation: str,
             dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    p = {
        "w_in": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(k2, (d_ff, d_model)) * s_out).astype(dtype),
    }
    if activation == "swiglu":
        p["w_gate"] = (jax.random.normal(k3, (d_model, d_ff)) * s_in).astype(dtype)
    return p


def apply_mlp(p: dict, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    h = x @ p["w_in"]
    if activation == "swiglu":
        g = x @ p["w_gate"]
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = maybe_shard(h, P(None, None, "model"))
    return h @ p["w_out"]


# -------------------------------------------------------------- embeddings --
def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32) -> dict:
    return {
        "table": (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)
    }


def embed_tokens(p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["table"], tokens, axis=0)


def init_learned_pos(key, max_len: int, d_model: int, dtype=jnp.float32) -> dict:
    return {
        "pos_table": (jax.random.normal(key, (max_len, d_model)) * 0.02).astype(dtype)
    }


def learned_pos(p: dict, pos_id: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["pos_table"], pos_id, axis=0)


def unembed(table_or_head: jnp.ndarray, x: jnp.ndarray,
            softcap: float = 0.0) -> jnp.ndarray:
    logits = x @ table_or_head
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


def init_linear(key, d_in: int, d_out: int, dtype=jnp.float32,
                scale: Optional[float] = None) -> jnp.ndarray:
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * s).astype(dtype)
