"""Attention layers: GQA (with qk-norm, sliding window, logit softcap),
MedVerse DAG masking, MLA (DeepSeek-V3), and cross-attention (Whisper).

Two execution paths:
  * ``attention_forward``  — full-sequence training/prefill. Mask is
    computed on the fly from O(S) topology metadata (never materialized
    outside the attention op), either in one shot (``naive``) or per KV
    chunk with a running-softmax (``chunked`` — the flash-style pure-JAX
    variant used by the §Perf memory-term hillclimb).
  * ``attention_decode``   — one-token serve step against a dense KV
    cache (dry-run path). The engine's CPU paged path lives in
    ``repro/engine``; the TPU kernel in ``repro/kernels/decode_attention``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.masks import NEG_INF
from ..core.topology import PAD_SEG
from .config import ATTN, LOCAL_ATTN, MLAConfig, ModelConfig
from .layers import apply_norm, apply_rope, init_linear, init_norm, maybe_shard


@dataclasses.dataclass
class TopoBatch:
    """Batched per-token topology metadata (see core.topology)."""

    seg_id: jnp.ndarray    # (B, S) int32
    layer_id: jnp.ndarray  # (B, S) int32
    pos_id: jnp.ndarray    # (B, S) int32
    seg_visible: Optional[jnp.ndarray] = None  # (B, n_seg, n_seg) bool

    @staticmethod
    def linear(batch: int, seq: int) -> "TopoBatch":
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))
        zeros = jnp.zeros((batch, seq), jnp.int32)
        return TopoBatch(seg_id=zeros, layer_id=zeros, pos_id=pos)


def allowed_block(
    topo: TopoBatch,
    cfg: ModelConfig,
    kind: str,
    q_slice: slice,
    kv_start: jnp.ndarray,
    kv_len: int,
) -> jnp.ndarray:
    """Compute the boolean allowed-matrix for a (q-block, kv-block) tile
    directly from metadata — Eq. 3 (+ optional strict ancestor mask and
    sliding window), O(block^2) with O(S) inputs.

    q_slice is static; kv_start may be traced (chunked scan).
    """
    b = topo.seg_id.shape[0]
    seg_q = topo.seg_id[:, q_slice]
    lay_q = topo.layer_id[:, q_slice]
    pos_q = topo.pos_id[:, q_slice]
    q0 = q_slice.start or 0
    sq = seg_q.shape[1]

    def dslice(a):
        return jax.lax.dynamic_slice_in_dim(a, kv_start, kv_len, axis=1)

    seg_k, lay_k, pos_k = dslice(topo.seg_id), dslice(topo.layer_id), dslice(topo.pos_id)
    iq = q0 + jnp.arange(sq)
    ik = kv_start + jnp.arange(kv_len)
    causal = ik[None, :] <= iq[:, None]                      # packed order
    same_layer = lay_q[:, :, None] == lay_k[:, None, :]
    same_seg = seg_q[:, :, None] == seg_k[:, None, :]
    ok = causal[None] & ~(same_layer & ~same_seg)
    if cfg.ancestor_mask and topo.seg_visible is not None:
        safe_q = jnp.maximum(seg_q, 0)
        safe_k = jnp.maximum(seg_k, 0)
        vis = jax.vmap(lambda v, sq, sk: v[sq][:, sk])(
            topo.seg_visible, safe_q, safe_k
        )  # (B, Sq, Sk)
        ok = ok & vis
    valid = (seg_q != PAD_SEG)[:, :, None] & (seg_k != PAD_SEG)[:, None, :]
    ok = ok & valid
    if kind == LOCAL_ATTN:
        diff = pos_q[:, :, None] - pos_k[:, None, :]
        ok = ok & (diff >= 0) & (diff < cfg.sliding_window)
    return ok  # (B, Sq, Sk)


def _gqa_scores(q, k, scale, softcap):
    # q: (B, Sq, Kv, G, H), k: (B, Sk, Kv, H) -> (B, Kv, G, Sq, Sk)
    s = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    return s


# ------------------------------------------------------------------ GQA ----
def init_attention(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    if cross:
        nkv = nh  # whisper cross-attn has full kv heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "wq": init_linear(k1, d, nh * hd, dt),
        "wk": init_linear(k2, d, nkv * hd, dt),
        "wv": init_linear(k3, d, nkv * hd, dt),
        "wo": init_linear(k4, nh * hd, d, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_norm(hd)
        p["k_norm"] = init_norm(hd)
    return p


def _project_qkv(p, x, cfg: ModelConfig, pos_id, cross_kv=None):
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    nh = cfg.n_heads
    nkv = nh if cross_kv is not None else cfg.n_kv_heads
    q = (x @ p["wq"]).reshape(b, s, nh, hd)
    src = cross_kv if cross_kv is not None else x
    k = (src @ p["wk"]).reshape(b, src.shape[1], nkv, hd)
    v = (src @ p["wv"]).reshape(b, src.shape[1], nkv, hd)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm", cfg.norm_eps)
        k = apply_norm(p["k_norm"], k, "rmsnorm", cfg.norm_eps)
    if cfg.pos_embedding == "rope" and cross_kv is None:
        q = apply_rope(q, pos_id, cfg.rope_theta)
        k = apply_rope(k, pos_id, cfg.rope_theta)
    return q, k, v


def attention_forward(
    p: dict,
    x: jnp.ndarray,
    topo: TopoBatch,
    cfg: ModelConfig,
    kind: str = ATTN,
) -> jnp.ndarray:
    """Full-sequence self-attention with the MedVerse DAG mask."""
    b, s, d = x.shape
    hd, nh, nkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    g = nh // nkv
    q, k, v = _project_qkv(p, x, cfg, topo.pos_id)
    q = maybe_shard(q, P(("pod", "data"), None, "model", None))
    k = maybe_shard(k, P(("pod", "data"), None, "model", None))
    q = q.reshape(b, s, nkv, g, hd)
    scale = 1.0 / math.sqrt(hd)

    if cfg.attn_impl == "chunked" and s > cfg.attn_chunk_kv:
        out = _chunked_attention(q, k, v, topo, cfg, kind, scale)
    else:
        allowed = allowed_block(topo, cfg, kind, slice(0, s), jnp.int32(0), s)
        bias = jnp.where(allowed[:, None, None], 0.0, NEG_INF)  # (B,1,1,S,S)
        scores = _gqa_scores(q, k, scale, cfg.attn_logit_softcap) + bias
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    out = out.reshape(b, s, nh * hd).astype(x.dtype)
    return out @ p["wo"]


def _chunked_attention(q, k, v, topo, cfg, kind, scale):
    """Flash-style streaming softmax over KV chunks (pure JAX).

    Keeps peak intermediate memory at O(S * chunk) instead of O(S^2):
    the §Perf "memory-term" optimization, and the oracle structure the
    Pallas dag_attention kernel mirrors.
    """
    b, s, nkv, g, hd = q.shape
    ck = cfg.attn_chunk_kv
    n_chunks = (s + ck - 1) // ck
    pad = n_chunks * ck - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qf = q.astype(jnp.float32)

    def body(carry, ci):
        m, l, acc = carry
        start = ci * ck
        k_c = jax.lax.dynamic_slice_in_dim(k, start, ck, axis=1)
        v_c = jax.lax.dynamic_slice_in_dim(v, start, ck, axis=1)
        allowed = allowed_block(topo, cfg, kind, slice(0, s), start, ck)
        # chunk tokens beyond s are padding -> masked via seg PAD on pad_to;
        # but k was padded freshly here, so mask tail explicitly:
        in_range = (start + jnp.arange(ck)) < s
        allowed = allowed & in_range[None, None, :]
        bias = jnp.where(allowed[:, None, None], 0.0, NEG_INF)
        sc = _gqa_scores(qf, k_c, scale, cfg.attn_logit_softcap) + bias
        m_new = jnp.maximum(m, sc.max(axis=-1))
        p_ = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p_.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p_, v_c.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, nkv, g, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, nkv, g, s), jnp.float32)
    a0 = jnp.zeros((b, nkv, g, s, hd), jnp.float32)
    if cfg.scan_layers:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                      jnp.arange(n_chunks))
    else:
        # unrolled (dry-run roofline mode): XLA cost_analysis counts scan
        # bodies once, so honest measurement requires unrolling here too
        carry = (m0, l0, a0)
        for ci in range(n_chunks):
            carry, _ = body(carry, jnp.int32(ci))
        m, l, acc = carry
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.einsum("bkgqh->bqkgh", out)


def attention_decode(
    p: dict,
    x_t: jnp.ndarray,          # (B, 1, D)
    cache: dict,               # {"k","v"}: (B, S_max, Kv, H)
    write_index: jnp.ndarray,  # scalar int32 — current cache length
    kv_pos: jnp.ndarray,       # (B, S_max) adaptive positions of cache slots
    kv_valid: jnp.ndarray,     # (B, S_max) bool
    q_pos: jnp.ndarray,        # (B,) adaptive position of the new token
    cfg: ModelConfig,
    kind: str = ATTN,
) -> Tuple[jnp.ndarray, dict]:
    b = x_t.shape[0]
    hd, nh, nkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    g = nh // nkv
    q = (x_t @ p["wq"]).reshape(b, 1, nh, hd)
    k_t = (x_t @ p["wk"]).reshape(b, 1, nkv, hd)
    v_t = (x_t @ p["wv"]).reshape(b, 1, nkv, hd)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm", cfg.norm_eps)
        k_t = apply_norm(p["k_norm"], k_t, "rmsnorm", cfg.norm_eps)
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, q_pos[:, None], cfg.rope_theta)
        k_t = apply_rope(k_t, q_pos[:, None], cfg.rope_theta)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_t.astype(cache["k"].dtype), write_index, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_t.astype(cache["v"].dtype), write_index, axis=1)
    kv_valid = kv_valid.at[:, write_index].set(True) if kv_valid.ndim == 2 else kv_valid
    kv_pos = kv_pos.at[:, write_index].set(q_pos)

    visible = kv_valid & (kv_pos <= q_pos[:, None])          # (B, S)
    if kind == LOCAL_ATTN:
        diff = q_pos[:, None] - kv_pos
        visible = visible & (diff >= 0) & (diff < cfg.sliding_window)
    q = q.reshape(b, 1, nkv, g, hd)
    scale = 1.0 / math.sqrt(hd)
    sc = _gqa_scores(q, k, scale, cfg.attn_logit_softcap)     # (B,Kv,G,1,S)
    sc = sc + jnp.where(visible[:, None, None, None, :], 0.0, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    out = out.reshape(b, 1, nh * hd).astype(x_t.dtype)
    y = out @ p["wo"]
    return y, {"k": k, "v": v, "kv_pos": kv_pos, "kv_valid": kv_valid}


# ---------------------------------------------------------- cross-attn ----
def cross_attention_forward(p: dict, x: jnp.ndarray, enc: jnp.ndarray,
                            cfg: ModelConfig) -> jnp.ndarray:
    b, s, d = x.shape
    hd, nh = cfg.resolved_head_dim, cfg.n_heads
    q, k, v = _project_qkv(p, x, cfg, None, cross_kv=enc)
    scale = 1.0 / math.sqrt(hd)
    sc = jnp.einsum("bqnh,bsnh->bnqs", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bnqs,bsnh->bqnh", w, v.astype(jnp.float32))
    return out.reshape(b, s, nh * hd).astype(x.dtype) @ p["wo"]


# ------------------------------------------------------------------ MLA ----
def init_mla(key, cfg: ModelConfig) -> dict:
    m: MLAConfig = cfg.mla
    d, nh = cfg.d_model, cfg.n_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    return {
        "w_dq": init_linear(ks[0], d, m.q_lora_rank, dt),
        "q_norm": init_norm(m.q_lora_rank),
        "w_uq": init_linear(ks[1], m.q_lora_rank, nh * qk_hd, dt),
        "w_dkv": init_linear(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dt),
        "kv_norm": init_norm(m.kv_lora_rank),
        "w_uk": init_linear(ks[3], m.kv_lora_rank, nh * m.qk_nope_head_dim, dt),
        "w_uv": init_linear(ks[4], m.kv_lora_rank, nh * m.v_head_dim, dt),
        "wo": init_linear(ks[5], nh * m.v_head_dim, d, dt),
    }


def mla_forward(p: dict, x: jnp.ndarray, topo: TopoBatch,
                cfg: ModelConfig, kind: str = ATTN) -> jnp.ndarray:
    """Training/prefill MLA with DAG mask. Up-projects the compressed KV
    (the memory win is in the *cache*, i.e. decode)."""
    m: MLAConfig = cfg.mla
    b, s, d = x.shape
    nh = cfg.n_heads
    cq = apply_norm(p["q_norm"], x @ p["w_dq"], "rmsnorm", cfg.norm_eps)
    q = (cq @ p["w_uq"]).reshape(b, s, nh, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    dkv = x @ p["w_dkv"]
    c_kv, k_rope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    c_kv = apply_norm(p["kv_norm"], c_kv, "rmsnorm", cfg.norm_eps)
    k_rope = k_rope[:, :, None, :]  # single shared rope head
    q_rope = apply_rope(q_rope, topo.pos_id, cfg.rope_theta)
    k_rope = apply_rope(k_rope, topo.pos_id, cfg.rope_theta)
    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, nh, m.qk_nope_head_dim)
    v = (c_kv @ p["w_uv"]).reshape(b, s, nh, m.v_head_dim)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    sc = (
        jnp.einsum("bqnh,bsnh->bnqs", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32))
        + jnp.einsum("bqnh,bsoh->bnqs", q_rope.astype(jnp.float32),
                     jnp.broadcast_to(k_rope, (b, s, 1, m.qk_rope_head_dim)).astype(jnp.float32))
    ) * scale
    allowed = allowed_block(topo, cfg, kind, slice(0, s), jnp.int32(0), s)
    sc = sc + jnp.where(allowed[:, None], 0.0, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bnqs,bsnh->bqnh", w, v.astype(jnp.float32))
    out = out.reshape(b, s, nh * m.v_head_dim).astype(x.dtype)
    return out @ p["wo"]


def mla_decode(
    p: dict,
    x_t: jnp.ndarray,
    cache: dict,               # {"c_kv": (B,S,rank), "k_rope": (B,S,rope_hd)}
    write_index: jnp.ndarray,
    kv_pos: jnp.ndarray,
    kv_valid: jnp.ndarray,
    q_pos: jnp.ndarray,
    cfg: ModelConfig,
    kind: str = ATTN,
) -> Tuple[jnp.ndarray, dict]:
    """Decode with *weight absorption*: scores are taken directly against
    the compressed cache — no per-step up-projection of S entries."""
    m: MLAConfig = cfg.mla
    b = x_t.shape[0]
    nh = cfg.n_heads
    cq = apply_norm(p["q_norm"], x_t @ p["w_dq"], "rmsnorm", cfg.norm_eps)
    q = (cq @ p["w_uq"]).reshape(b, 1, nh, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, q_pos[:, None], cfg.rope_theta)
    dkv = x_t @ p["w_dkv"]
    c_kv_t, k_rope_t = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    c_kv_t = apply_norm(p["kv_norm"], c_kv_t, "rmsnorm", cfg.norm_eps)
    k_rope_t = apply_rope(k_rope_t[:, :, None, :], q_pos[:, None], cfg.rope_theta)[:, :, 0, :]
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv_t.astype(cache["c_kv"].dtype), write_index, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_t.astype(cache["k_rope"].dtype), write_index, axis=1)
    kv_pos = kv_pos.at[:, write_index].set(q_pos)
    kv_valid = kv_valid.at[:, write_index].set(True)
    # absorb: q_nope (B,1,N,hn) @ w_uk^T (N*hn <- rank): fold per head
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, nh, m.qk_nope_head_dim)
    q_abs = jnp.einsum("bqnh,rnh->bqnr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))              # (B,1,N,rank)
    sc = jnp.einsum("bqnr,bsr->bnqs", q_abs, c_kv.astype(jnp.float32))
    sc = sc + jnp.einsum("bqnh,bsh->bnqs", q_rope.astype(jnp.float32),
                         k_rope.astype(jnp.float32))
    sc = sc / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    visible = kv_valid & (kv_pos <= q_pos[:, None])
    sc = sc + jnp.where(visible[:, None, None, :], 0.0, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1)
    # out in compressed space, then up-project via w_uv absorbed into wo
    ctx = jnp.einsum("bnqs,bsr->bqnr", w, c_kv.astype(jnp.float32))  # (B,1,N,rank)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, nh, m.v_head_dim)
    out = jnp.einsum("bqnr,rnh->bqnh", ctx, w_uv.astype(jnp.float32))
    out = out.reshape(b, 1, nh * m.v_head_dim).astype(x_t.dtype)
    return out @ p["wo"], {"c_kv": c_kv, "k_rope": k_rope,
                           "kv_pos": kv_pos, "kv_valid": kv_valid}
