"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block = [gelu branch] x [causal conv1d -> RG-LRU] -> elementwise gate ->
output projection. The recurrence

    a_t = exp(-c * softplus(Lambda) * sigmoid(r_t))
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

is evaluated with ``jax.lax.associative_scan`` (parallel prefix — the
TPU-native formulation; the Pallas kernel in ``kernels/rglru_scan``
implements the same contraction with explicit VMEM blocking).

Gates are block-diagonal per head as in Griffin.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig, RGLRUConfig
from .layers import init_linear


def init_rglru(key, cfg: ModelConfig) -> dict:
    rc: RGLRUConfig = cfg.rglru
    d = cfg.d_model
    w = rc.lru_width or d
    nh = rc.n_heads or 1
    hd = w // nh
    ks = jax.random.split(key, 7)
    dt = jnp.dtype(cfg.dtype)
    s_h = 1.0 / math.sqrt(hd)
    # Lambda init so that a ~ Uniform(0.9, 0.999) at r=1 (Griffin A.2)
    u = jax.random.uniform(ks[5], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / rc.c_constant))  # softplus^-1
    return {
        "w_y": init_linear(ks[0], d, w, dt),
        "w_x": init_linear(ks[1], d, w, dt),
        "conv_w": (jax.random.normal(ks[2], (rc.conv1d_width, w)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "gate_i": (jax.random.normal(ks[3], (nh, hd, hd)) * s_h).astype(dt),
        "gate_r": (jax.random.normal(ks[4], (nh, hd, hd)) * s_h).astype(dt),
        "lambda": lam.astype(jnp.float32),
        "w_out": init_linear(ks[6], w, d, dt),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Per-channel causal conv via shifted adds. x: (B,S,W); w: (K,W)."""
    k = w.shape[0]
    y = x * w[k - 1]
    for i in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        y = y + shifted * w[k - 1 - i]
    return y + b


def _gates(x: jnp.ndarray, p: dict, rc: RGLRUConfig, w: int):
    nh = rc.n_heads or 1
    hd = w // nh
    xh = x.reshape(*x.shape[:-1], nh, hd)
    i_t = jax.nn.sigmoid(jnp.einsum("...hd,hde->...he", xh, p["gate_i"]))
    r_t = jax.nn.sigmoid(jnp.einsum("...hd,hde->...he", xh, p["gate_r"]))
    return i_t.reshape(x.shape), r_t.reshape(x.shape)


def rglru_scan_ref(a: jnp.ndarray, bx: jnp.ndarray,
                   h0: jnp.ndarray = None) -> jnp.ndarray:
    """h_t = a_t * h_{t-1} + bx_t via associative scan. a, bx: (B,S,W)."""
    if h0 is not None:
        # fold the initial state into the first step's additive term
        bx = bx.at[:, 0].add(a[:, 0] * h0)
        a = a.at[:, 0].set(jnp.zeros_like(a[:, 0]))
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2
    aa, hh = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return hh


def rglru_forward(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    rc = cfg.rglru
    w = rc.lru_width or cfg.d_model
    y_branch = jax.nn.gelu(x @ p["w_y"])
    xb = _causal_conv(x @ p["w_x"], p["conv_w"], p["conv_b"])
    i_t, r_t = _gates(xb, p, rc, w)
    log_a = -rc.c_constant * jax.nn.softplus(p["lambda"]) * r_t.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = (i_t * xb).astype(jnp.float32)
    bx = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    h = rglru_scan_ref(a, bx)
    return (h.astype(x.dtype) * y_branch) @ p["w_out"]


def rglru_decode(
    p: dict, x_t: jnp.ndarray, state: dict, cfg: ModelConfig
) -> Tuple[jnp.ndarray, dict]:
    """Single-token step. state = {"h": (B,W) f32, "conv": (B,K-1,W)}."""
    rc = cfg.rglru
    w = rc.lru_width or cfg.d_model
    k = rc.conv1d_width
    y_branch = jax.nn.gelu(x_t @ p["w_y"])                    # (B,1,W)
    xb_t = (x_t @ p["w_x"])[:, 0]                             # (B,W)
    window = jnp.concatenate([state["conv"], xb_t[:, None]], axis=1)  # (B,K,W)
    conv = jnp.einsum("bkw,kw->bw", window, p["conv_w"]) + p["conv_b"]
    i_t, r_t = _gates(conv, p, rc, w)
    log_a = -rc.c_constant * jax.nn.softplus(p["lambda"]) * r_t.astype(jnp.float32)
    a = jnp.exp(log_a)
    bx = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i_t * conv
    ).astype(jnp.float32)
    h = a * state["h"] + bx
    out = (h.astype(x_t.dtype)[:, None] * y_branch) @ p["w_out"]
    return out, {"h": h, "conv": window[:, 1:]}


def rglru_init_state(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    rc = cfg.rglru
    w = rc.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, rc.conv1d_width - 1, w), dtype),
    }
