"""Model substrate: configs, layers, attention (GQA/MLA + MedVerse DAG
masking), MoE, RG-LRU, RWKV6, and the transformer assembly."""

from .attention import TopoBatch
from .config import (
    ATTN,
    LOCAL_ATTN,
    RGLRU,
    RWKV6,
    EncoderConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RGLRUConfig,
    RWKV6Config,
    VisionConfig,
    validate_config,
)
from .transformer import (
    compute_stages,
    decode_step,
    encoder_forward,
    forward,
    forward_with_hidden,
    init_cache,
    init_params,
    mtp_forward,
    prefill_cross_kv,
)

__all__ = [
    "TopoBatch",
    "ATTN",
    "LOCAL_ATTN",
    "RGLRU",
    "RWKV6",
    "EncoderConfig",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "RGLRUConfig",
    "RWKV6Config",
    "VisionConfig",
    "validate_config",
    "compute_stages",
    "decode_step",
    "encoder_forward",
    "forward",
    "forward_with_hidden",
    "init_cache",
    "init_params",
    "mtp_forward",
    "prefill_cross_kv",
]
