"""Explicit mesh context for model code.

Model layers that need *explicit* collective schedules (MoE expert
parallelism via shard_map, distributed decode attention) read the active
mesh from here. The launch layer sets it; unit tests on CPU leave it
unset and the layers fall back to single-device local math.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax

_state = threading.local()


def set_mesh(mesh: Optional[jax.sharding.Mesh],
             data_axes: Tuple[str, ...] = ("data",),
             model_axis: str = "model") -> None:
    _state.mesh = mesh
    _state.data_axes = data_axes
    _state.model_axis = model_axis


def get_mesh() -> Optional[jax.sharding.Mesh]:
    return getattr(_state, "mesh", None)


def data_axes() -> Tuple[str, ...]:
    return getattr(_state, "data_axes", ("data",))


def model_axis() -> str:
    return getattr(_state, "model_axis", "model")


@contextlib.contextmanager
def use_mesh(mesh: jax.sharding.Mesh,
             data_axes: Tuple[str, ...] = ("data",),
             model_axis: str = "model"):
    prev = (get_mesh(), globals(), )
    prev_axes = (getattr(_state, "data_axes", ("data",)),
                 getattr(_state, "model_axis", "model"))
    prev_mesh = get_mesh()
    set_mesh(mesh, data_axes, model_axis)
    try:
        with mesh:
            yield mesh
    finally:
        set_mesh(prev_mesh, *prev_axes)
