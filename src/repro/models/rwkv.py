"""RWKV-6 "Finch" time-mix + channel-mix blocks (arXiv:2404.05892).

Core recurrence per head (head_dim n):

    S_t = diag(w_t) @ S_{t-1} + k_t v_t^T          # data-dependent decay
    y_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t

with per-token, per-channel decay w_t = exp(-exp(wb + lora_w(x))) — the
Finch contribution vs RWKV-5's static decay. Training uses a time scan
(the Pallas ``rwkv6_scan`` kernel blocks it over chunks); decode carries
the (B, H, n, n) state — O(1) in sequence length, which is why rwkv6-3b
is a ``long_500k`` architecture.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig, RWKV6Config
from .layers import init_linear

MIX_NAMES = ("r", "k", "v", "w", "g")


def init_rwkv_tm(key, cfg: ModelConfig) -> dict:
    rw: RWKV6Config = cfg.rwkv
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "mu_x": jnp.full((d,), 0.5, dt),
        "mix_lora_a": init_linear(ks[0], d, 5 * rw.mix_lora, dt, scale=0.01),
        "mix_lora_b": (jax.random.normal(ks[1], (5, rw.mix_lora, d)) * 0.01).astype(dt),
        "mu": (jax.random.uniform(ks[2], (5, d)) * 0.5 + 0.25).astype(dt),
        "w_r": init_linear(ks[3], d, d, dt),
        "w_k": init_linear(ks[4], d, d, dt),
        "w_v": init_linear(ks[5], d, d, dt),
        "w_g": init_linear(ks[6], d, d, dt),
        "w_o": init_linear(ks[7], d, d, dt),
        "decay_base": jnp.full((d,), -1.0, jnp.float32),
        "decay_lora_a": init_linear(ks[8], d, rw.decay_lora, dt, scale=0.01),
        "decay_lora_b": init_linear(ks[9], rw.decay_lora, d, dt, scale=0.01),
        "bonus_u": (jax.random.normal(ks[10], (d,)) * 0.1).astype(jnp.float32),
        "ln_scale": jnp.ones((d,), jnp.float32),  # per-head groupnorm scale
    }
    return p


def _token_shift(x: jnp.ndarray, x_prev_last: jnp.ndarray = None) -> jnp.ndarray:
    """x_{t-1} with zero (or carried) first element. x: (B,S,D)."""
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if x_prev_last is not None:
        shifted = shifted.at[:, 0].set(x_prev_last)
    return shifted


def _mix_inputs(p: dict, x: jnp.ndarray, x_prev: jnp.ndarray, rw: RWKV6Config):
    xx = x_prev - x
    xxx = x + xx * p["mu_x"]
    lora = jnp.tanh(xxx @ p["mix_lora_a"])                 # (B,S,5*L)
    lora = lora.reshape(*x.shape[:-1], 5, rw.mix_lora)
    delta = jnp.einsum("bsfl,fld->bsfd", lora, p["mix_lora_b"])  # (B,S,5,D)
    mixed = x[..., None, :] + xx[..., None, :] * (p["mu"] + delta)
    return {n: mixed[..., i, :] for i, n in enumerate(MIX_NAMES)}


def _rkvwg(p: dict, mixed: dict, cfg: ModelConfig):
    r = mixed["r"] @ p["w_r"]
    k = mixed["k"] @ p["w_k"]
    v = mixed["v"] @ p["w_v"]
    g = jax.nn.silu(mixed["g"] @ p["w_g"])
    log_w = -jnp.exp(
        p["decay_base"]
        + (jnp.tanh(mixed["w"] @ p["decay_lora_a"]) @ p["decay_lora_b"]).astype(jnp.float32)
    )  # (B,S,D), always < 0 => decay in (0,1)
    return r, k, v, g, log_w


def wkv_scan_ref(
    r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    w: jnp.ndarray, u: jnp.ndarray, head_dim: int,
    s0: jnp.ndarray = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential oracle. r,k,v,w: (B,S,D); u: (D,). Returns (y, s_final)
    with y (B,S,D), state (B,H,n,n)."""
    b, s, d = r.shape
    h = d // head_dim
    rs = r.reshape(b, s, h, head_dim).astype(jnp.float32)
    ks_ = k.reshape(b, s, h, head_dim).astype(jnp.float32)
    vs = v.reshape(b, s, h, head_dim).astype(jnp.float32)
    ws = w.reshape(b, s, h, head_dim).astype(jnp.float32)
    us = u.reshape(h, head_dim)
    state = (jnp.zeros((b, h, head_dim, head_dim), jnp.float32)
             if s0 is None else s0.astype(jnp.float32))

    def step(st, inp):
        r_t, k_t, v_t, w_t = inp  # each (B,H,n)
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,n,n)
        y = jnp.einsum("bhij,bhi->bhj", st + us[..., :, None] * kv, r_t)
        st = w_t[..., :, None] * st + kv
        return st, y

    xs = (rs.transpose(1, 0, 2, 3), ks_.transpose(1, 0, 2, 3),
          vs.transpose(1, 0, 2, 3), ws.transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(step, state, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d)
    return y, state


def _group_norm(y: jnp.ndarray, scale: jnp.ndarray, head_dim: int,
                eps: float = 1e-5) -> jnp.ndarray:
    shp = y.shape
    yh = y.reshape(*shp[:-1], shp[-1] // head_dim, head_dim)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + eps)
    return yh.reshape(shp) * scale


def rwkv_tm_forward(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    rw = cfg.rwkv
    x_prev = _token_shift(x)
    mixed = _mix_inputs(p, x, x_prev, rw)
    r, k, v, g, log_w = _rkvwg(p, mixed, cfg)
    w = jnp.exp(log_w)
    y, _ = wkv_scan_ref(r, k, v, w, p["bonus_u"], rw.head_dim)
    y = _group_norm(y, p["ln_scale"], rw.head_dim)
    return (y.astype(x.dtype) * g) @ p["w_o"]


def rwkv_tm_decode(p: dict, x_t: jnp.ndarray, state: dict,
                   cfg: ModelConfig) -> Tuple[jnp.ndarray, dict]:
    """state = {"wkv": (B,H,n,n) f32, "shift": (B,D)}."""
    rw = cfg.rwkv
    x_prev = state["shift"][:, None, :]
    mixed = _mix_inputs(p, x_t, x_prev, rw)
    r, k, v, g, log_w = _rkvwg(p, mixed, cfg)
    b, _, d = x_t.shape
    h, n = d // rw.head_dim, rw.head_dim
    r_t = r[:, 0].reshape(b, h, n).astype(jnp.float32)
    k_t = k[:, 0].reshape(b, h, n).astype(jnp.float32)
    v_t = v[:, 0].reshape(b, h, n).astype(jnp.float32)
    w_t = jnp.exp(log_w[:, 0]).reshape(b, h, n)
    u = p["bonus_u"].reshape(h, n)
    kv = k_t[..., :, None] * v_t[..., None, :]
    y = jnp.einsum("bhij,bhi->bhj", state["wkv"] + u[..., :, None] * kv, r_t)
    wkv = w_t[..., :, None] * state["wkv"] + kv
    y = _group_norm(y.reshape(b, 1, d), p["ln_scale"], rw.head_dim)
    out = (y.astype(x_t.dtype) * g) @ p["w_o"]
    return out, {"wkv": wkv, "shift": x_t[:, 0]}


# ------------------------------------------------------------ channel mix --
def init_rwkv_cm(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    return {
        "mu_k": jnp.full((d,), 0.5, dt),
        "mu_r": jnp.full((d,), 0.5, dt),
        "w_k": init_linear(ks[0], d, f, dt),
        "w_v": init_linear(ks[1], f, d, dt),
        "w_r": init_linear(ks[2], d, d, dt),
    }


def rwkv_cm_forward(p: dict, x: jnp.ndarray, x_prev_last=None) -> jnp.ndarray:
    x_prev = _token_shift(x, x_prev_last)
    xx = x_prev - x
    xk = x + xx * p["mu_k"]
    xr = x + xx * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    return jax.nn.sigmoid(xr @ p["w_r"]) * (k @ p["w_v"])


def rwkv_cm_decode(p: dict, x_t: jnp.ndarray, shift: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x_prev = shift[:, None, :]
    xx = x_prev - x_t
    xk = x_t + xx * p["mu_k"]
    xr = x_t + xx * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    return jax.nn.sigmoid(xr @ p["w_r"]) * (k @ p["w_v"]), x_t[:, 0]


def rwkv_init_state(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    h = d // cfg.rwkv.head_dim
    return {
        "wkv": jnp.zeros((batch, h, cfg.rwkv.head_dim, cfg.rwkv.head_dim), jnp.float32),
        "shift": jnp.zeros((batch, d), dtype),
        "cm_shift": jnp.zeros((batch, d), dtype),
    }
