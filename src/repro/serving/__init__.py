"""Continuous-batching serving subsystem: open request streams over the
engine's step-level API, pluggable admission policies, preemption
recovery, and per-request SLA metrics (TTFT / TPOT / e2e / goodput)."""

from .metrics import RequestMetrics, ServingReport
from .queue import (ChainAwarePolicy, FCFSPolicy, RequestQueue,
                    SchedulingPolicy, estimate_frontier_width, make_policy)
from .scheduler import ContinuousScheduler, ServeRequest

__all__ = [
    "ChainAwarePolicy",
    "ContinuousScheduler",
    "FCFSPolicy",
    "RequestMetrics",
    "RequestQueue",
    "SchedulingPolicy",
    "ServeRequest",
    "ServingReport",
    "estimate_frontier_width",
    "make_policy",
]
