"""Admission queue and scheduling policies for continuous batching.

The queue holds :class:`ServeRequest` objects that have *arrived* but not
yet been admitted into the engine. Every engine step the scheduler pops
as many requests as free slots allow — admission is mid-flight, not
per-batch. Preempted requests re-enter through a priority lane so they
are re-admitted (same rid, radix-cached prompt) before fresh work.

Policies decide *which* waiting request fills a freed slot:

* :class:`FCFSPolicy` — arrival order.
* :class:`ChainAwarePolicy` — prefers the request whose DAG frontier
  width best fills the currently idle slots: MedVerse requests fan out
  into ``width`` parallel decode streams right after planning, so
  admitting a wide plan into a nearly-empty engine converts idle slots
  into throughput, while a 1-wide serial request is the better fit for a
  single free slot. Falls back to FCFS among equals.
"""

from __future__ import annotations

from collections import deque
from typing import Deque, List, Optional

from ..core.plan import parse_plan


def estimate_frontier_width(plan_text: Optional[str]) -> int:
    """Width of the plan's first execution frontier (its dependency-free
    steps) — the stream burst that hits the engine right after Phase I.
    Unknown / unparseable plans count as width 1 (a single plan stream)."""
    if not plan_text:
        return 1
    try:
        dag = parse_plan(plan_text, lenient=True).to_dag()
    except Exception:
        return 1
    return max(len(dag.sources()), 1)


class SchedulingPolicy:
    name = "base"

    def select(self, waiting: List, free_slots: int) -> int:
        """Index into ``waiting`` of the next request to admit."""
        raise NotImplementedError


class FCFSPolicy(SchedulingPolicy):
    name = "fcfs"

    def select(self, waiting: List, free_slots: int) -> int:
        return 0


class ChainAwarePolicy(SchedulingPolicy):
    name = "chain-aware"

    def select(self, waiting: List, free_slots: int) -> int:
        best, best_width = 0, -1
        for i, req in enumerate(waiting):
            w = req.frontier_width
            if w <= free_slots and w > best_width:
                best, best_width = i, w
        # nothing fits the idle capacity exactly -> plain FCFS (a wider
        # plan still runs; its extra streams just queue inside the engine)
        return best if best_width > 0 else 0


def make_policy(policy) -> SchedulingPolicy:
    if isinstance(policy, SchedulingPolicy):
        return policy
    table = {"fcfs": FCFSPolicy, "chain-aware": ChainAwarePolicy,
             "chain_aware": ChainAwarePolicy}
    if policy not in table:
        raise ValueError(f"unknown policy {policy!r}; "
                         f"choose from {sorted(table)}")
    return table[policy]()


class RequestQueue:
    """Waiting room between arrival and engine admission."""

    def __init__(self, policy="fcfs"):
        self.policy = make_policy(policy)
        self._waiting: List = []
        self._preempted: Deque = deque()

    def push(self, req) -> None:
        self._waiting.append(req)

    def requeue(self, req) -> None:
        """Priority lane for preemption victims: re-admitted before any
        fresh request, FCFS among themselves."""
        self._preempted.append(req)

    def pop(self, free_slots: int):
        if self._preempted:
            return self._preempted.popleft()
        if not self._waiting:
            return None
        idx = self.policy.select(self._waiting, free_slots)
        return self._waiting.pop(idx)

    def push_front(self, req) -> None:
        """Return a request the engine could not admit (pool pressure at
        prefill); it keeps its place at the head of the line."""
        self._preempted.appendleft(req)

    def pending(self) -> List:
        """Every request still waiting for admission (priority lane
        first), without removing any."""
        return list(self._preempted) + list(self._waiting)

    def __len__(self) -> int:
        return len(self._waiting) + len(self._preempted)
