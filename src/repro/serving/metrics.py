"""Per-request SLA metrics and fleet-level aggregation for the
continuous-batching serving subsystem.

Every request records two clocks: wall time (seconds — the numbers an
operator cares about) and engine decode steps (deterministic — the
numbers tests and cross-machine comparisons care about). TTFT is
measured from *arrival*, not admission, so queueing delay under closed
batching shows up where it hurts.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

NAN = float("nan")


@dataclasses.dataclass
class RequestMetrics:
    t_arrival_s: float = NAN      # wall clock at arrival (eligibility)
    t_admit_s: float = NAN        # wall clock at engine admission
    t_first_token_s: float = NAN
    t_done_s: float = NAN
    arrival_step: int = -1        # scheduler step count at arrival
    admit_step: int = -1
    first_token_step: int = -1
    done_step: int = -1
    # compute clock (engine-lifetime attention FLOPs from the analytic
    # cost ledger, snapshotted by the scheduler; -1 when cost accounting
    # is off): deterministic like the step clock, but sensitive to
    # head-of-line prefill stalls the step clock cannot see — a
    # monolithic long-prompt prefill costs zero steps but all of its
    # FLOPs land inside every concurrent request's TTFT window
    arrival_flops: int = -1
    first_token_flops: int = -1
    n_tokens: int = 0             # decoded tokens across all DAG streams
    n_drafted: int = 0            # of those, committed from accepted drafts
    n_preemptions: int = 0
    # audit trail (empty / zero when EngineConfig.audit is off): final
    # disposition, decision verdict counts, and per-stage token timing
    # on the deterministic step clock (stage = "reason" | "critic" |
    # "guardrail" for DAG step streams; plan/conclusion carry no stage)
    disposition: str = ""
    verdicts: Dict[str, int] = dataclasses.field(default_factory=dict)
    stage_tokens: Dict[str, int] = dataclasses.field(default_factory=dict)
    stage_first_step: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    stage_last_step: Dict[str, int] = dataclasses.field(
        default_factory=dict)

    def note_stage_token(self, stage: str, step: int) -> None:
        self.stage_tokens[stage] = self.stage_tokens.get(stage, 0) + 1
        if stage not in self.stage_first_step:
            self.stage_first_step[stage] = step
        self.stage_last_step[stage] = step

    def stage_ttft_steps(self, stage: str) -> float:
        """Steps from engine admission to the stage's first token."""
        if stage not in self.stage_first_step or self.admit_step < 0:
            return NAN
        return float(self.stage_first_step[stage] - self.admit_step)

    def stage_tpot_steps(self, stage: str) -> float:
        """Steps per token after the stage's first, across its streams."""
        n = self.stage_tokens.get(stage, 0)
        if n <= 1:
            return NAN
        return (self.stage_last_step[stage]
                - self.stage_first_step[stage]) / (n - 1)

    @property
    def ttft_s(self) -> float:
        return self.t_first_token_s - self.t_arrival_s

    @property
    def ttft_steps(self) -> int:
        if self.first_token_step < 0 or self.arrival_step < 0:
            return -1
        return self.first_token_step - self.arrival_step

    @property
    def ttft_flops(self) -> float:
        """Engine attention FLOPs spent between this request's arrival
        and its first token — the deterministic TTFT that exposes
        prefill head-of-line blocking (see the field comment)."""
        if self.first_token_flops < 0 or self.arrival_flops < 0:
            return NAN
        return float(self.first_token_flops - self.arrival_flops)

    @property
    def tpot_s(self) -> float:
        """Time per output token after the first (the streaming cadence)."""
        if self.n_tokens <= 1:
            return NAN
        return (self.t_done_s - self.t_first_token_s) / (self.n_tokens - 1)

    @property
    def tpot_steps(self) -> float:
        """Decode steps per output token after the first — the
        deterministic-clock companion to :attr:`tpot_s`. Below 1.0
        means speculation committed more than one token per step."""
        if (self.n_tokens <= 1 or self.done_step < 0
                or self.first_token_step < 0):
            return NAN
        return (self.done_step - self.first_token_step) / (self.n_tokens - 1)

    @property
    def e2e_s(self) -> float:
        return self.t_done_s - self.t_arrival_s

    def meets_deadline(self, deadline_s: Optional[float]) -> bool:
        if deadline_s is None:
            return not math.isnan(self.e2e_s)
        return self.e2e_s <= deadline_s


def _stats(xs: List[float]) -> Dict[str, float]:
    xs = [x for x in xs if not math.isnan(x)]
    if not xs:
        return {"mean": NAN, "p50": NAN, "p95": NAN, "p99": NAN}
    a = np.asarray(xs, np.float64)
    return {"mean": float(a.mean()),
            "p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)),
            "p99": float(np.percentile(a, 99))}


@dataclasses.dataclass
class ServingReport:
    """Aggregate SLA view of one serving run (one policy, one workload)."""

    policy: str
    closed_batch: bool
    n_requests: int
    n_completed: int
    duration_s: float
    n_steps: int
    total_tokens: int
    throughput_tok_s: float
    throughput_req_s: float
    ttft_s: Dict[str, float]
    ttft_steps: Dict[str, float]
    tpot_s: Dict[str, float]
    e2e_s: Dict[str, float]
    goodput: float                # fraction finishing within the deadline
    deadline_s: Optional[float]
    n_preemptions: int
    # speculative decoding (all zero / NaN when the engine runs without
    # a drafter): committed tokens per engine step — the accepted-
    # tokens-per-step SLA companion to TPOT — plus the engine's
    # lifetime draft counters
    tokens_per_step: float = NAN
    n_drafted: int = 0
    spec_proposed: int = 0
    spec_accepted: int = 0
    spec_acceptance: float = NAN
    # deterministic-clock TPOT (decode steps per token after the first);
    # mean/p50/p95/p99 like the wall-clock stats above
    tpot_steps: Dict[str, float] = dataclasses.field(default_factory=dict)
    # compute-clock TTFT (engine attention FLOPs between arrival and
    # first token; NaN when cost accounting is off) — deterministic AND
    # stall-sensitive, the tail metric chunked prefill improves
    ttft_flops: Dict[str, float] = dataclasses.field(default_factory=dict)
    # verified serving (audit trail on; zero / NaN / empty otherwise):
    # requests whose AuditReport closed "verified", as a wall-clock rate
    # (verified_goodput, machine-dependent) and per deterministic decode
    # step (verified_per_step, CI-gateable), plus the disposition and
    # decision-verdict tallies and per-stage step-clock latency
    # breakdowns keyed by stage name
    n_verified: int = 0
    verified_goodput: float = NAN       # verified requests per wall second
    verified_per_step: float = NAN      # verified requests per decode step
    dispositions: Dict[str, int] = dataclasses.field(default_factory=dict)
    verdicts: Dict[str, int] = dataclasses.field(default_factory=dict)
    stage_ttft_steps: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)
    stage_tpot_steps: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)
    # engine telemetry snapshot (MedVerseEngine.metrics_registry().
    # snapshot()): page-pool lifetime counters, radix hit/miss, spec
    # stats, bucket histograms. None when the caller has no engine.
    engine: Optional[dict] = None

    @staticmethod
    def build(metrics: List[RequestMetrics], duration_s: float,
              n_steps: int, policy: str, closed_batch: bool = False,
              deadline_s: Optional[float] = None,
              spec_stats: Optional[Dict[str, int]] = None,
              engine_metrics: Optional[dict] = None) -> "ServingReport":
        done = [m for m in metrics if not math.isnan(m.t_done_s)]
        total_tokens = sum(m.n_tokens for m in metrics)
        good = sum(1 for m in done if m.meets_deadline(deadline_s))
        spec_stats = spec_stats or {}
        proposed = int(spec_stats.get("proposed", 0))
        accepted = int(spec_stats.get("accepted", 0))
        dispositions: Dict[str, int] = {}
        verdicts: Dict[str, int] = {}
        for m in metrics:
            if m.disposition:
                dispositions[m.disposition] = (
                    dispositions.get(m.disposition, 0) + 1)
            for k, v in m.verdicts.items():
                verdicts[k] = verdicts.get(k, 0) + v
        n_verified = dispositions.get("verified", 0)
        stages = sorted({s for m in metrics for s in m.stage_tokens})
        return ServingReport(
            policy=policy, closed_batch=closed_batch,
            n_requests=len(metrics), n_completed=len(done),
            duration_s=duration_s, n_steps=n_steps,
            total_tokens=total_tokens,
            throughput_tok_s=total_tokens / max(duration_s, 1e-9),
            throughput_req_s=len(done) / max(duration_s, 1e-9),
            ttft_s=_stats([m.ttft_s for m in done]),
            ttft_steps=_stats([float(m.ttft_steps) for m in done
                               if m.ttft_steps >= 0]),
            tpot_s=_stats([m.tpot_s for m in done]),
            e2e_s=_stats([m.e2e_s for m in done]),
            goodput=good / max(len(metrics), 1),
            deadline_s=deadline_s,
            n_preemptions=sum(m.n_preemptions for m in metrics),
            tokens_per_step=total_tokens / n_steps if n_steps > 0 else NAN,
            n_drafted=sum(m.n_drafted for m in metrics),
            spec_proposed=proposed,
            spec_accepted=accepted,
            spec_acceptance=accepted / proposed if proposed > 0 else NAN,
            tpot_steps=_stats([m.tpot_steps for m in done]),
            ttft_flops=_stats([m.ttft_flops for m in done]),
            n_verified=n_verified,
            verified_goodput=(n_verified / max(duration_s, 1e-9)
                              if dispositions else NAN),
            verified_per_step=(n_verified / n_steps
                               if dispositions and n_steps > 0 else NAN),
            dispositions=dispositions,
            verdicts=verdicts,
            stage_ttft_steps={
                s: _stats([m.stage_ttft_steps(s) for m in done])
                for s in stages},
            stage_tpot_steps={
                s: _stats([m.stage_tpot_steps(s) for m in done])
                for s in stages},
            engine=engine_metrics,
        )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        return (f"policy={self.policy}{'(closed)' if self.closed_batch else ''} "
                f"reqs={self.n_completed}/{self.n_requests} "
                f"steps={self.n_steps} "
                f"tput={self.throughput_tok_s:.1f}tok/s "
                f"ttft={self.ttft_s['mean']*1e3:.0f}ms"
                f"({self.ttft_steps['mean']:.1f}st) "
                f"tpot={self.tpot_s['mean']*1e3:.1f}ms "
                f"tok/step={self.tokens_per_step:.2f} "
                f"goodput={self.goodput:.2f} "
                f"preempt={self.n_preemptions}"
                + (f" spec={self.spec_accepted}/{self.spec_proposed}"
                   f"({self.spec_acceptance:.0%})"
                   if self.spec_proposed > 0 else "")
                + (f" verified={self.n_verified}/{self.n_requests}"
                   f"(vgp={self.verified_goodput:.2f}/s)"
                   if self.dispositions else ""))
