"""Continuous-batching scheduler over the engine's step-level API.

vLLM-style open-system serving: requests arrive over time (Poisson in
the benchmark, scripted in tests), wait in a :class:`RequestQueue`, and
are admitted into the engine *every step* as slots free up — a late
arrival never waits for an in-flight batch to drain. The scheduler also
owns the failure path: when the engine preempts a request under page
pressure, the victim re-enters the queue's priority lane and is
re-prefilled (cheap via the radix cache) once pages free up.

Two clocks:

* ``clock="wall"`` — arrivals in seconds; what a real deployment uses.
* ``clock="step"`` — arrivals in engine decode steps; fully
  deterministic, what tests and cross-machine comparisons use.

``closed_batch=True`` turns the same machinery into the historical
baseline (admit only into an idle engine, i.e. ``generate()`` called
batch after batch) so continuous-vs-closed is measured on identical
code paths.

The scheduler is stage-aware: token events from stage-typed DAG
streams feed per-stage TTFT/TPOT breakdowns, audit events (decisions
and dispositions from the engine's :class:`~repro.obs.audit.AuditTrail`)
update per-request verdict tallies and the report's verified-goodput
block, and the engine itself prioritizes a ready critic transition
whose verdict unblocks >= 2 sibling branches (``critic_priority``
trace instants carry the frontier-unblocking count).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

from ..engine import MedVerseEngine, OutOfPagesError, SamplingParams
from ..engine.engine import GenResult, StepEvent
from .metrics import RequestMetrics, ServingReport
from .queue import RequestQueue, estimate_frontier_width, make_policy


@dataclasses.dataclass
class ServeRequest:
    """One open request stream flowing through the serving subsystem."""

    prompt: str
    plan: Optional[str] = None
    sampling: Optional[SamplingParams] = None
    arrival: float = 0.0          # scheduler-clock units (steps or secs)
    deadline_s: Optional[float] = None
    # streaming callback: (rid, token_id, text_piece) per decoded token
    on_token: Optional[Callable[[int, int, str], None]] = None
    # audit callback: (rid, AuditRecord) per stage decision / disposition
    # (fires only when the engine runs with EngineConfig.audit on)
    on_audit: Optional[Callable[[int, object], None]] = None
    rid: int = -1
    # pending|queued|running|preempted|done|failed (failed = could never
    # fit the page pool, even with nothing else running)
    state: str = "pending"
    result: Optional[GenResult] = None
    metrics: RequestMetrics = dataclasses.field(default_factory=RequestMetrics)

    @property
    def frontier_width(self) -> int:
        if not hasattr(self, "_width"):
            self._width = estimate_frontier_width(self.plan)
        return self._width


class ContinuousScheduler:
    def __init__(self, engine: MedVerseEngine, policy="fcfs",
                 clock: str = "wall", closed_batch: bool = False,
                 deadline_s: Optional[float] = None):
        assert clock in ("wall", "step"), clock
        self.engine = engine
        self.policy = make_policy(policy)
        self.queue = RequestQueue(self.policy)
        self.clock = clock
        self.closed_batch = closed_batch
        self.deadline_s = deadline_s
        self.step_count = 0
        self.finished: List[ServeRequest] = []
        self._pending: List[ServeRequest] = []   # submitted, not arrived
        self._running: Dict[int, ServeRequest] = {}
        self._t0: Optional[float] = None
        # tracing: reuse the engine's recorder so serving events (arrival,
        # admission, queue depth) interleave with engine events on the
        # same two clocks; NULL_RECORDER when tracing is off
        self.obs = engine.obs

    # ---------------------------------------------------------- clock ------
    def now(self) -> float:
        if self.clock == "step":
            return float(self.step_count)
        if self._t0 is None:
            return 0.0
        return time.monotonic() - self._t0

    def _flops_now(self) -> int:
        """Engine-lifetime attention FLOPs — the deterministic compute
        clock behind ``RequestMetrics.ttft_flops`` (machine-independent
        like the step clock, but it advances through prefill work, so
        head-of-line prompt stalls are visible). -1 when the engine runs
        without cost accounting."""
        c = self.engine.cost
        return int(c.total("attn_flops")) if c is not None else -1

    # ------------------------------------------------------- submission ----
    def submit(self, req: ServeRequest) -> ServeRequest:
        """Register a request; it enters the queue at ``req.arrival``."""
        self._pending.append(req)
        self._pending.sort(key=lambda r: r.arrival)
        return req

    def _release_arrivals(self) -> None:
        now = self.now()
        while self._pending and self._pending[0].arrival <= now:
            req = self._pending.pop(0)
            req.state = "queued"
            req.metrics.t_arrival_s = time.monotonic() - (self._t0 or 0.0)
            req.metrics.arrival_step = self.step_count
            req.metrics.arrival_flops = self._flops_now()
            self.queue.push(req)
            if self.obs.enabled:
                self.obs.instant("arrival", "serving",
                                 sched_step=self.step_count,
                                 queue_depth=len(self.queue))

    # -------------------------------------------------------- admission ----
    def _admit(self) -> None:
        if self.closed_batch and self.engine.n_requests() > 0:
            return   # baseline semantics: drain the whole batch first
        while len(self.queue) and self.engine.has_capacity():
            req = self.queue.pop(self.engine.n_free_slots())
            if req is None:
                break
            try:
                rid = self.engine.add_request(
                    req.prompt, plan=req.plan, sampling=req.sampling,
                    rid=req.rid if req.rid >= 0 else None)
            except OutOfPagesError:
                if self.engine.n_requests() == 0:
                    # even an idle engine cannot prefill it: the prompt
                    # can never run — fail it, keep serving the rest
                    req.state = "failed"
                    self.finished.append(req)
                    continue
                # pool too tight for prefill right now; hold the request
                # at the head of the line and retry once pages free up
                self.queue.push_front(req)
                break
            req.rid = rid
            req.state = "running"
            req.metrics.t_admit_s = time.monotonic() - (self._t0 or 0.0)
            req.metrics.admit_step = self.step_count
            self._running[rid] = req
            if self.obs.enabled:
                self.obs.instant(
                    "admit", "serving", rid=rid,
                    wait_steps=self.step_count - req.metrics.arrival_step,
                    queue_depth=len(self.queue))

    # ------------------------------------------------------------ events ---
    def _dispatch(self, ev: StepEvent) -> None:
        req = self._running.get(ev.rid)
        if req is None:
            return
        m = req.metrics
        if ev.kind == "token":
            if m.first_token_step < 0:
                m.first_token_step = self.step_count
                m.t_first_token_s = time.monotonic() - (self._t0 or 0.0)
                m.first_token_flops = self._flops_now()
            m.n_tokens += 1
            if ev.drafted:
                m.n_drafted += 1
            if ev.stage:
                # stage-typed DAG step stream: per-stage token counts
                # and first/last step marks back the report's per-stage
                # TTFT/TPOT breakdowns (deterministic step clock)
                m.note_stage_token(ev.stage, self.step_count)
            if req.on_token is not None:
                req.on_token(ev.rid, ev.token,
                             self.engine.tok.decode([ev.token]))
        elif ev.kind == "audit":
            rec = ev.audit
            if rec.kind == "decision":
                m.verdicts[rec.verdict.status] = (
                    m.verdicts.get(rec.verdict.status, 0) + 1)
            else:
                m.disposition = rec.disposition
            if req.on_audit is not None:
                req.on_audit(ev.rid, rec)
        elif ev.kind == "done":
            m.t_done_s = time.monotonic() - (self._t0 or 0.0)
            m.done_step = self.step_count
            req.result = ev.result
            req.state = "done"
            self.finished.append(req)
            del self._running[ev.rid]
        elif ev.kind == "preempted":
            # victim keeps its rid (sampling seed + radix-cached prompt);
            # priority lane re-admits it as soon as pages free up
            m.n_preemptions += 1
            req.state = "preempted"
            del self._running[ev.rid]
            self.queue.requeue(req)

    # -------------------------------------------------------------- loop ---
    def tick(self) -> bool:
        """One scheduling cycle: release arrivals, admit into free slots,
        run one engine step, dispatch its events. Returns True while any
        request is pending, queued, or running."""
        if self._t0 is None:
            self._t0 = time.monotonic()
        self._release_arrivals()
        self._admit()
        if self.obs.enabled:
            self.obs.counter("queue_depth",
                             {"queued": len(self.queue),
                              "running": len(self._running),
                              "pending": len(self._pending)})
        try:
            events = self.engine.step()
        except OutOfPagesError:
            # no preemption victim left (a lone request that cannot fit,
            # or one past max_preemptions): fail just that request so the
            # rest of the fleet keeps serving
            events = []
            victim = max(self.engine.active_rids, default=-1)
            req = self._running.pop(victim, None)
            self.engine.abort(victim)
            if req is not None:
                req.state = "failed"
                self.finished.append(req)
        # the step counter is the deterministic clock: it advances even
        # on idle ticks so future arrivals still become due
        self.step_count += 1
        for ev in events:
            self._dispatch(ev)
        if (not events and self.clock == "wall" and self._pending
                and not self._running and not len(self.queue)):
            time.sleep(0.001)   # idle gap before the next wall arrival
        return bool(self._pending or len(self.queue) or self._running)

    def run(self, workload: Optional[List[ServeRequest]] = None,
            max_steps: int = 1_000_000) -> ServingReport:
        """Drive a workload to completion and return its SLA report."""
        for req in workload or []:
            self.submit(req)
        self._t0 = time.monotonic()
        steps0 = self.step_count
        while self.tick():
            if self.step_count - steps0 > max_steps:
                raise RuntimeError(
                    f"serving run exceeded {max_steps} steps "
                    f"({len(self.finished)} finished, "
                    f"{len(self._running)} running, "
                    f"{len(self.queue)} queued)")
        return self.report()

    def report(self) -> ServingReport:
        reqs = (self.finished + list(self._running.values())
                + self.queue.pending() + self._pending)
        duration = time.monotonic() - (self._t0 or time.monotonic())
        # requests closed outside the event stream (failure-path aborts)
        # still got a disposition from the engine's trail: backfill it
        if self.engine.audit is not None:
            for r in reqs:
                if r.rid >= 0 and not r.metrics.disposition:
                    rep = self.engine.audit.reports.get(r.rid)
                    if rep is not None:
                        r.metrics.disposition = rep.disposition
        return ServingReport.build(
            [r.metrics for r in reqs], duration_s=duration,
            n_steps=self.step_count,
            policy=self.policy.name, closed_batch=self.closed_batch,
            deadline_s=self.deadline_s,
            spec_stats=getattr(self.engine, "spec_stats", None),
            engine_metrics=self.engine.metrics_registry().snapshot())
