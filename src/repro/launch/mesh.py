"""Production mesh construction.

Target: TPU v5e, 256 chips/pod. Single-pod mesh (16 data x 16 model);
multi-pod adds a leading "pod" axis (2 x 16 x 16 = 512 chips).

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (dryrun.py does this)."
        )
    return jax.make_mesh(
        shape, axes, devices=devices[:n],
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_host_mesh() -> jax.sharding.Mesh:
    """Trivial 1x1 mesh for CPU tests of the pjit path."""
    return jax.make_mesh(
        (1, 1), ("data", "model"), devices=jax.devices()[:1],
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def mesh_axes(mesh: jax.sharding.Mesh) -> Tuple[Tuple[str, ...], str]:
    """Returns (data_axes, model_axis) for a production mesh."""
    names = mesh.axis_names
    data_axes = tuple(a for a in names if a in ("pod", "data"))
    return data_axes, "model"
