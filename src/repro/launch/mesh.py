"""Production mesh construction.

Target: TPU v5e, 256 chips/pod. Single-pod mesh (16 data x 16 model);
multi-pod adds a leading "pod" axis (2 x 16 x 16 = 512 chips).

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax


def _axis_type_kwargs(n: int) -> dict:
    """jax.sharding.AxisType landed after 0.4.x; omit the kwarg on older
    jax (meshes default to Auto axes there anyway)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def set_global_mesh(mesh: jax.sharding.Mesh) -> None:
    """``jax.set_mesh`` where available (>= 0.6); on older jax, enter the
    legacy thread-global mesh context instead."""
    if hasattr(jax, "set_mesh"):
        jax.set_mesh(mesh)
    else:
        mesh.__enter__()


def as_shardings(mesh: jax.sharding.Mesh, tree):
    """Map a PartitionSpec pytree to NamedShardings. ``jax.jit`` on
    jax < 0.6 only accepts ``Sharding`` leaves in in/out_shardings;
    NamedSharding works on every version. ``None`` leaves (meaning
    "infer") pass through."""
    is_spec = lambda s: isinstance(s, jax.sharding.PartitionSpec)
    return jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(mesh, s) if is_spec(s) else s,
        tree, is_leaf=is_spec)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (dryrun.py does this)."
        )
    return jax.make_mesh(
        shape, axes, devices=devices[:n], **_axis_type_kwargs(len(axes)),
    )


def make_host_mesh() -> jax.sharding.Mesh:
    """Trivial 1x1 mesh for CPU tests of the pjit path."""
    return jax.make_mesh(
        (1, 1), ("data", "model"), devices=jax.devices()[:1],
        **_axis_type_kwargs(2),
    )


def mesh_axes(mesh: jax.sharding.Mesh) -> Tuple[Tuple[str, ...], str]:
    """Returns (data_axes, model_axis) for a production mesh."""
    names = mesh.axis_names
    data_axes = tuple(a for a in names if a in ("pod", "data"))
    return data_axes, "model"
