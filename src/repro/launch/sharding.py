"""Per-architecture sharding rules: PartitionSpec pytrees for params,
optimizer state, train batches, and decode caches.

Policy (baseline; §Perf iterates on this):
  * tensor parallel over ``model``: attention heads / FFN hidden / vocab /
    MoE experts / recurrent channels.
  * batch over the data axes (``("pod","data")`` on the multi-pod mesh).
  * FSDP (ZeRO-style) over the data axes for large archs so optimizer
    states fit: the non-model dim of every matrix is sharded over data.
  * every rule checks divisibility and degrades to replication rather
    than producing an invalid spec.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from ..models.config import ModelConfig

# archs whose optimizer state cannot fit replicated over data
FSDP_ARCHS = {"qwen3-32b", "dbrx-132b", "deepseek-v3-671b", "medverse-7b",
              "phi-3-vision-4.2b"}


def _div(n: int, mesh: jax.sharding.Mesh, axis) -> bool:
    if axis is None:
        return True
    if isinstance(axis, tuple):
        size = int(np.prod([mesh.shape[a] for a in axis]))
    else:
        size = mesh.shape[axis]
    return n % size == 0


def _spec_for(path: str, shape: Tuple[int, ...], mesh, model, fsdp,
              cfg: Optional[ModelConfig] = None):
    """Choose the spec for one (unstacked) parameter."""
    name = path.split("/")[-1]
    d = len(shape)
    msize = mesh.shape[model]
    # Attention projections may only shard on whole-head boundaries:
    # splitting head_dim across shards turns the score contraction into
    # partial sums and XLA all-reduces the (S,S) f32 scores — measured
    # 223 GB/device on gemma3 prefill (EXPERIMENTS.md §Perf H2-iter4).
    if cfg is not None and name in ("wq", "wo"):
        head_ok = cfg.n_heads % msize == 0
    elif cfg is not None and name in ("wk", "wv"):
        head_ok = cfg.n_kv_heads % msize == 0
    else:
        head_ok = True
    if name in ("wq", "wk", "wv", "wo") and not head_ok:
        return P(*((fsdp,) + (None,) * (d - 1))) if d == 2 and _div(
            shape[0], mesh, fsdp) else P(*([None] * d))

    def ok(spec_axes):
        # degrade per-dim if not divisible
        final = []
        for dim, ax in zip(shape, spec_axes):
            final.append(ax if _div(dim, mesh, ax) else None)
        return P(*final)

    if name in ("table",):          # embed (V, D)
        return ok((model, fsdp))
    if name == "lm_head":
        return ok((fsdp, model))
    if name == "pos_table":
        return ok((None, model))
    if name in ("wq", "wk", "wv", "w_in", "w_gate", "w_y", "w_x",
                "w_r", "w_k", "w_v", "w_g", "w_uq", "w_uk", "w_uv"):
        return ok((fsdp, model)) if d == 2 else P(*([None] * d))
    if name in ("wo", "w_out", "w_o"):
        return ok((model, fsdp)) if d == 2 else P(*([None] * d))
    if name in ("w_dq", "w_dkv", "router", "proj",
                "mix_lora_a", "decay_lora_a", "decay_lora_b",
                "vision_proj"):
        return ok((fsdp, None)) if d == 2 else P(*([None] * d))
    if name in ("conv_w",):
        return ok((None, model))
    if name in ("conv_b", "lambda", "bonus_u", "ln_scale"):
        return ok((model,))
    if name in ("gate_i", "gate_r"):
        return ok((model, None, None))
    if name == "mix_lora_b":
        return P(*([None] * d))
    return P(*([None] * d))  # norms, scalars, mu vectors


def _moe_expert_spec(path, shape, mesh, model, fsdp):
    name = path.split("/")[-1]
    if name in ("w_in", "w_gate"):
        sp = [model, fsdp, None]
    elif name == "w_out":
        sp = [model, None, fsdp]
    else:
        return None
    final = [ax if _div(dim, mesh, ax) else None for dim, ax in zip(shape, sp)]
    return P(*final)


def param_specs(cfg: ModelConfig, params: Any, mesh: jax.sharding.Mesh,
                fsdp: Optional[bool] = None) -> Any:
    """PartitionSpec pytree matching ``params`` (arrays or SDS)."""
    data_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    model = "model"
    fsdp_ax = data_axes if (fsdp if fsdp is not None
                            else cfg.name in FSDP_ARCHS) else None

    def visit(path_entries, leaf) -> P:
        keys = []
        for p in path_entries:
            keys.append(str(getattr(p, "key", getattr(p, "idx", p))))
        path = "/".join(keys)
        shape = tuple(leaf.shape)
        stacked = "stages" in keys and "ffn" not in () # placeholder
        # leading stack axis for stage params: detect via known leaf rank
        # by trying the rule on the trailing dims.
        is_stage = "stages" in keys or "layers" in keys
        core_shape = shape[1:] if is_stage and len(shape) >= 1 else shape
        # MoE expert tensors are 3-D (E, D, F) *before* stacking
        if "ffn" in keys and len(core_shape) == 3 and cfg.moe is not None:
            sp = _moe_expert_spec(path, core_shape, mesh, model, fsdp_ax)
            if sp is None:
                sp = _spec_for(path, core_shape, mesh, model, fsdp_ax, cfg)
        else:
            sp = _spec_for(path, core_shape, mesh, model, fsdp_ax, cfg)
        if is_stage:
            sp = P(*((None,) + tuple(sp)))
        if len(tuple(sp)) != len(shape):
            # pad/trim defensively to rank
            axes = (tuple(sp) + (None,) * len(shape))[: len(shape)]
            sp = P(*axes)
        return sp

    return jax.tree_util.tree_map_with_path(visit, params)


def opt_state_specs(cfg: ModelConfig, pspecs: Any) -> Dict[str, Any]:
    return {
        "mu": pspecs,
        "nu": pspecs,
        "step": P(),
    }


def batch_specs(cfg: ModelConfig, batch: Any, mesh,
                seq_shard: bool = False) -> Any:
    """Batch dim over the data axes; with ``seq_shard`` the sequence dim
    is additionally sharded over ``model`` (hybrid TP+SP — shrinks the
    per-layer tensor-parallel all-reduce by the model-axis size; §Perf
    iteration H2)."""
    data_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
    msize = mesh.shape.get("model", 1) if hasattr(mesh.shape, "get") else mesh.shape["model"]

    def visit(path_entries, leaf):
        shape = tuple(leaf.shape)
        axes = [None] * len(shape)
        if len(shape) >= 1 and shape[0] % dsize == 0 and shape[0] > 1:
            axes[0] = data_axes
        if (seq_shard and len(shape) >= 2 and shape[1] % msize == 0
                and shape[1] > 1):
            axes[1] = "model"
        return P(*axes)

    return jax.tree_util.tree_map_with_path(visit, batch)


def cache_specs_tree(cfg: ModelConfig, cache: Any, mesh) -> Any:
    """Decode cache sharding: batch over data; KV-heads over model when
    divisible, else sequence over model, else replicate."""
    data_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
    msize = mesh.shape["model"]

    def visit(path_entries, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p)))
                for p in path_entries]
        name = keys[-1]
        shape = tuple(leaf.shape)
        batch_ax = (data_axes if len(shape) >= 1 else None)

        def b(dim_idx):
            return (data_axes if shape[dim_idx] % dsize == 0 and
                    shape[dim_idx] > 1 else None)

        if name in ("kv_pos", "kv_valid"):      # (B, S)
            return P(b(0), None)
        if name in ("k", "v") and len(shape) == 5:   # (n, B, S, kv, hd)
            if shape[3] % msize == 0:
                return P(None, b(1), None, "model", None)
            if shape[2] % msize == 0:
                return P(None, b(1), "model", None, None)
            return P(None, b(1), None, None, None)
        if name in ("cross_k", "cross_v"):       # (n, B, T, nh, hd)
            if shape[3] % msize == 0:
                return P(None, b(1), None, "model", None)
            return P(None, b(1), None, None, None)
        if name == "c_kv" or name == "k_rope":   # (n, B, S, r)
            if shape[2] % msize == 0:
                return P(None, b(1), "model", None)
            return P(None, b(1), None, None)
        if name == "pos" or name == "valid":     # local ring (n, B, buf)
            return P(None, b(1), None)
        if name in ("h",) and len(shape) == 3:   # rglru state (n, B, W)
            return P(None, b(1), "model" if shape[2] % msize == 0 else None)
        if name == "conv" and len(shape) == 4:   # (n, B, K-1, W)
            return P(None, b(1), None,
                     "model" if shape[3] % msize == 0 else None)
        if name == "wkv":                        # (n, B, H, hd, hd)
            return P(None, b(1),
                     "model" if shape[2] % msize == 0 else None, None, None)
        if name in ("shift", "cm_shift"):        # (n, B, D)
            return P(None, b(1), "model" if shape[2] % msize == 0 else None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(visit, cache)
