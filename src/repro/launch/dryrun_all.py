import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Batch driver: runs every (arch x shape x mesh) dry-run as a
subprocess (fresh process per pair keeps XLA state and memory bounded on
the 1-core container) and aggregates results/dryrun/*.json.

Passes:
  scanned   — compile-proof + memory_analysis, single-pod AND multi-pod
  unrolled  — roofline source (scan bodies unrolled so cost_analysis
              counts every layer), single-pod only
"""

import argparse
import json
import subprocess
import sys
import time

ARCHS = [
    "llama3.2-1b", "gemma3-1b", "starcoder2-3b", "rwkv6-3b",
    "recurrentgemma-2b", "whisper-large-v3", "phi-3-vision-4.2b",
    "medverse-7b", "qwen3-32b", "dbrx-132b", "deepseek-v3-671b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def run(arch, shape, multi_pod=False, no_scan=False, out="results/dryrun",
        timeout=5400):
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out", out]
    if multi_pod:
        cmd.append("--multi-pod")
    if no_scan:
        cmd.append("--no-scan")
    t0 = time.time()
    try:
        r = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=timeout,
                           env={**os.environ, "PYTHONPATH": "src"})
        ok = r.returncode == 0
        tail = (r.stdout + r.stderr)[-400:]
    except subprocess.TimeoutExpired:
        ok, tail = False, "TIMEOUT"
    tag = f"{arch}/{shape}/{'pod2' if multi_pod else 'pod1'}" + (
        "/unrolled" if no_scan else "")
    print(f"[{time.strftime('%H:%M:%S')}] {tag}: "
          f"{'OK' if ok else 'FAIL'} ({time.time()-t0:.0f}s)", flush=True)
    if not ok:
        print(tail, flush=True)
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pass", dest="mode", default="scanned",
                    choices=["scanned", "multipod", "unrolled"])
    ap.add_argument("--archs", nargs="*", default=ARCHS)
    ap.add_argument("--shapes", nargs="*", default=SHAPES)
    args = ap.parse_args()
    n_fail = 0
    for arch in args.archs:
        for shape in args.shapes:
            if args.mode == "scanned":
                n_fail += not run(arch, shape)
            elif args.mode == "multipod":
                n_fail += not run(arch, shape, multi_pod=True)
            else:
                n_fail += not run(arch, shape, no_scan=True)
    print(f"DONE pass={args.mode} failures={n_fail}", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
