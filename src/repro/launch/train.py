"""Production training launcher: ``python -m repro.launch.train --arch
<id> ...``. Builds the mesh, shards params/optimizer/batch with the
sharding rules, and runs pjit train steps.

On this CPU container use ``--host-mesh --smoke`` (1x1 mesh, reduced
config); on a real v5e pod the same entry point drives the 16x16 mesh
(set --production), and 2x16x16 with --multi-pod.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import SHAPES, get_config
from ..data import Corpus, encode_example, make_batches
from ..models import init_params, meshctx
from ..train import AdamWConfig, init_opt_state, make_train_step
from .mesh import (as_shardings, make_host_mesh, make_production_mesh,
                   mesh_axes, set_global_mesh)
from .sharding import batch_specs, opt_state_specs, param_specs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--host-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--items", type=int, default=200)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = (make_host_mesh() if args.host_mesh
            else make_production_mesh(multi_pod=args.multi_pod))
    daxes, maxis = mesh_axes(mesh)
    set_global_mesh(mesh)
    meshctx.set_mesh(mesh, daxes, maxis)
    print(f"mesh={dict(mesh.shape)} arch={cfg.name} "
          f"params~{cfg.param_count()/1e6:.1f}M")

    corpus = Corpus.build(n_items=args.items, n_clusters=32)
    assert corpus.tokenizer.vocab_size <= cfg.vocab_size, (
        "smoke vocab too small for corpus; use --items fewer or full cfg")
    encoded = [encode_example(e, corpus.tokenizer) for e in corpus.train]
    batches = make_batches(encoded, args.batch, args.seq)
    print(f"{len(encoded)} examples -> {len(batches)} batches")

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = init_opt_state(params)
    pspecs = param_specs(cfg, params, mesh)
    ospecs = opt_state_specs(cfg, pspecs)
    bspecs = batch_specs(cfg, batches[0], mesh)
    step = jax.jit(
        make_train_step(cfg, AdamWConfig(learning_rate=args.lr,
                                         total_steps=args.steps)),
        in_shardings=as_shardings(mesh, (pspecs, ospecs, bspecs)),
        out_shardings=as_shardings(mesh, (pspecs, ospecs, None)),
        donate_argnums=(0, 1),
    )
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in
                 batches[i % len(batches)].items()}
        params, opt_state, metrics = step(params, opt_state, batch)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                  f"ce {float(metrics['ce']):.4f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    print("done")


if __name__ == "__main__":
    main()
