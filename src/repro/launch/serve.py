"""Production serving launcher: ``python -m repro.launch.serve --arch
<id>`` — batched single-token decode steps (serve_step) against a dense
KV cache under the production sharding, for any assigned architecture
(incl. SSM/MLA archs the paged engine doesn't cover).

On CPU use --host-mesh --smoke; the same entry point drives real pods.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import decode_step, init_cache, init_params, meshctx
from .mesh import make_host_mesh, make_production_mesh, mesh_axes
from .sharding import cache_specs_tree, param_specs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--host-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = (make_host_mesh() if args.host_mesh
            else make_production_mesh(multi_pod=args.multi_pod))
    daxes, maxis = mesh_axes(mesh)
    jax.set_mesh(mesh)
    meshctx.set_mesh(mesh, daxes, maxis)
    print(f"mesh={dict(mesh.shape)} arch={cfg.name}")

    params = init_params(jax.random.PRNGKey(0), cfg)
    cache = init_cache(cfg, args.batch, args.max_len)
    pspecs = param_specs(cfg, params, mesh, fsdp=False)
    cspecs = cache_specs_tree(cfg, cache, mesh)
    step = jax.jit(
        lambda p, c, t, wi, qp: decode_step(p, c, t, wi, qp, cfg),
        in_shardings=(pspecs, cspecs, None, None, None),
        out_shardings=(None, cspecs),
        donate_argnums=(1,),
    )
    tok = jnp.zeros((args.batch,), jnp.int32)
    t0 = time.time()
    for i in range(args.steps):
        logits, cache = step(params, cache, tok, jnp.int32(i),
                             jnp.full((args.batch,), i, jnp.int32))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    dt = time.time() - t0
    print(f"{args.steps} steps x batch {args.batch}: "
          f"{args.steps*args.batch/dt:.1f} tok/s "
          f"({dt/args.steps*1e3:.1f} ms/step); sample token ids "
          f"{np.asarray(tok)[:4]}")


if __name__ == "__main__":
    main()
