"""Production serving launcher: ``python -m repro.launch.serve --arch
<id>`` — batched single-token decode steps (serve_step) against a dense
KV cache under the production sharding, for any assigned architecture
(incl. SSM/MLA archs the paged engine doesn't cover).

``--engine`` switches to the MedVerse paged engine (attention archs
only): DAG-scheduled decode with chain bucketing and the radix prompt
cache, optionally ``--async-frontier`` for per-transition marking
advance. ``--no-radix`` disables cross-request prefix reuse.
``--attention-backend dense|pallas`` selects the attention hot path
(dense gather+SDPA vs the Pallas paged-decode / DAG-prefill kernels);
``--compiled-kernels`` disables interpret mode on real TPUs.
``--plan-file`` / ``--prompts-file`` replace the built-in toy plan and
prompts (the tokenizer trains on whatever corpus is served).
``--continuous`` serves the workload through the continuous-batching
scheduler with Poisson arrivals at ``--arrival-rate`` req/s instead of
one closed batch. ``--speculative`` turns on per-chain speculative
decoding (``--drafter ngram|radix``, ``--draft-len N``) — same
temperature-0 output in fewer decode iterations.

Observability: ``--trace PATH`` records the structured engine trace and
writes it to ``PATH`` (native JSONL) plus ``PATH``'s Chrome trace-event
twin, loadable at https://ui.perfetto.dev, and prints the per-request
DAG timeline summary; ``--metrics`` prints the engine metrics registry
in Prometheus text format after the run; ``--metrics-port N`` serves
that registry live over HTTP while the run is in flight (``/metrics``
Prometheus text — cost counters, bucket histograms, compile counters —
plus ``/healthz``). All work in closed-batch and ``--continuous`` mode.

On CPU use --host-mesh --smoke; the same entry point drives real pods.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import decode_step, init_cache, init_params, meshctx
from .mesh import (as_shardings, make_host_mesh, make_production_mesh,
                   mesh_axes, set_global_mesh)
from .sharding import cache_specs_tree, param_specs

_ENGINE_PLAN = (
    "<Plan> "
    "<Outline> Transient Step 1: assess history ; Dependency: [] </Outline> "
    "<Outline> Transient Step 2: assess labs ; Dependency: [] </Outline> "
    "<Outline> Transient Step 3: check consistency ; Dependency: [1, 2] ; "
    "Stage: critic </Outline> "
    "<Outline> Transient Step 4: synthesize diagnosis ; Dependency: [3] "
    "</Outline> "
    "<Outline> Transient Step 5: screen contraindications ; "
    "Dependency: [3] ; Stage: guardrail </Outline> "
    "</Plan>")

_TOY_CORPUS = ("patient case history labs assess synthesize diagnosis "
               "check consistency screen contraindications "
               "Transient Step 1: 2: 3: 4: 5: Dependency: Stage: critic "
               "guardrail [] [1] [2] [3] [1, 2]")


def _load_workload(args):
    """(prompts, plan) from --prompts-file/--plan-file, falling back to
    the built-in toy workload."""
    plan = _ENGINE_PLAN
    if args.plan_file:
        with open(args.plan_file) as f:
            plan = f.read().strip()
    if args.prompts_file:
        with open(args.prompts_file) as f:
            prompts = [ln.strip() for ln in f if ln.strip()]
        if not prompts:
            raise SystemExit(f"--prompts-file {args.prompts_file}: empty")
    else:
        prompts = [f"patient case {i} history labs"
                   for i in range(args.requests or args.batch)]
    return prompts, plan


def run_engine(args) -> None:
    """Serve through the paged MedVerse engine on the default device."""
    from ..data.tokenizer import Tokenizer
    from ..engine import EngineConfig, MedVerseEngine

    cfg = get_config(args.arch, smoke=args.smoke)
    prompts, plan = _load_workload(args)
    # the tokenizer trains on the actual served corpus (prompts + plan),
    # not a hardcoded toy string, so real workloads round-trip
    tok = Tokenizer.train([_TOY_CORPUS, plan] + prompts)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ecfg = EngineConfig(
        max_slots=args.batch, page_size=16, n_pages=2048,
        max_chain_len=512, max_step_tokens=8, max_conclusion_tokens=8,
        async_frontier=args.async_frontier,
        radix_cache=not args.no_radix, plan_override=plan,
        speculative=args.speculative, drafter=args.drafter,
        draft_len=args.draft_len, trace=args.trace,
        audit=args.audit_log, prefill_chunk=args.prefill_chunk)
    if args.attention_backend:
        ecfg.attention_backend = args.attention_backend
    if args.kv_dtype:
        ecfg.kv_dtype = args.kv_dtype
    ecfg.kernel_interpret = not args.compiled_kernels
    eng = MedVerseEngine(params, cfg, tok, ecfg)
    metrics_srv = None
    if args.metrics_port is not None:
        from ..obs.server import MetricsServer
        metrics_srv = MetricsServer(
            lambda: eng.metrics_registry().to_prom_text(),
            port=args.metrics_port).start()
        print(f"metrics: {metrics_srv.address}/metrics "
              f"(healthz: {metrics_srv.address}/healthz)")
    buckets = eng.warmup()
    spec_str = (f" speculative={ecfg.drafter}/{ecfg.draft_len}"
                if ecfg.speculative else "")
    print(f"arch={cfg.name} engine async_frontier={ecfg.async_frontier} "
          f"radix={ecfg.radix_cache} "
          f"attention={ecfg.attention_backend}"
          f"{'' if ecfg.kernel_interpret else ' (compiled)'}"
          f" kv={ecfg.kv_dtype}"
          f"{f' prefill_chunk={ecfg.prefill_chunk}' if ecfg.prefill_chunk else ''}"
          f"{spec_str} warmed buckets={buckets}")
    try:
        if args.continuous:
            _run_continuous(args, eng, prompts, plan)
            _print_observability(args, eng)
            return
        t0 = time.time()
        res = eng.generate(prompts)
        dt = time.time() - t0
        n_tok = sum(r.n_tokens for r in res)
        print(f"{len(res)} requests, {n_tok} tokens in {dt:.2f}s "
              f"({n_tok/dt:.1f} tok/s, {eng.last_iters} decode iters); "
              f"radix hits={eng.radix.hits} misses={eng.radix.misses}; "
              f"pages used={eng.alloc.used} "
              f"pinned={eng.alloc.pinned_pages}; "
              f"buckets={dict(sorted(eng.bucket_hist.items()))}")
        _print_spec_stats(eng)
        _print_observability(args, eng)
    finally:
        if metrics_srv is not None:
            metrics_srv.close()


def _print_observability(args, eng) -> None:
    """--trace: dump JSONL + Chrome exports and the per-request DAG
    timeline; --audit-log: dump the clinical audit trail and its verdict
    tallies; --metrics: Prometheus text dump of the engine registry."""
    if args.trace:
        from ..obs import summarize
        jsonl_path, chrome_path = eng.dump_trace()
        print(f"trace: {len(eng.obs.events)} events -> {jsonl_path}; "
              f"Perfetto (https://ui.perfetto.dev): {chrome_path}")
        lines = summarize(eng.obs.events)
        if lines:
            print("DAG timelines (steps, per request):")
            print(lines)
    if args.audit_log:
        path = eng.dump_audit()
        c = eng.audit.counts()
        print(f"audit: {c['records']} records -> {path}; "
              f"verdicts pass={c['verdict_pass']} "
              f"fail={c['verdict_fail']} abstain={c['verdict_abstain']}; "
              f"dispositions verified={c['verified']} "
              f"refuted={c['refuted']} unverified={c['unverified']}")
    if args.metrics:
        print(eng.metrics_registry().to_prom_text(), end="")


def _print_spec_stats(eng) -> None:
    s = eng.spec_stats
    if s["steps"] == 0:
        return
    acc = s["accepted"] / s["proposed"] if s["proposed"] else float("nan")
    print(f"speculative: {s['tokens']} tokens in {s['steps']} steps "
          f"({s['tokens']/s['steps']:.2f} tok/step); drafts "
          f"accepted={s['accepted']}/{s['proposed']} ({acc:.0%}), "
          f"forced batched={s['forced_batched']}")


def _run_continuous(args, eng, prompts, plan) -> None:
    """Open-system serving: Poisson arrivals through the continuous
    scheduler, SLA report at the end."""
    from ..serving import ContinuousScheduler, ServeRequest

    rng = np.random.default_rng(0)
    gaps = rng.exponential(1.0 / args.arrival_rate, size=len(prompts))
    arrivals = np.cumsum(gaps)
    workload = [ServeRequest(prompt=p, plan=plan, arrival=float(a))
                for p, a in zip(prompts, arrivals)]
    sched = ContinuousScheduler(eng, policy=args.policy, clock="wall")
    rep = sched.run(workload)
    print(f"continuous policy={args.policy} "
          f"arrival_rate={args.arrival_rate}/s: {rep.summary()}")
    print(f"radix hits={eng.radix.hits} misses={eng.radix.misses}; "
          f"pages used={eng.alloc.used} pinned={eng.alloc.pinned_pages}; "
          f"preemptions={eng.preemptions}")
    _print_spec_stats(eng)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--host-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--engine", action="store_true",
                    help="serve via the paged MedVerse engine")
    ap.add_argument("--async-frontier", action="store_true",
                    help="engine mode: per-transition marking advance")
    ap.add_argument("--no-radix", action="store_true",
                    help="engine mode: disable radix prompt cache")
    ap.add_argument("--attention-backend", default=None,
                    choices=["dense", "pallas"],
                    help="engine mode: attention hot path — dense "
                         "gather+SDPA or the Pallas paged/DAG kernels "
                         "(default: $ENGINE_ATTENTION_BACKEND or dense)")
    ap.add_argument("--compiled-kernels", action="store_true",
                    help="engine mode: run Pallas kernels compiled "
                         "(Mosaic, real TPU) instead of interpret mode")
    ap.add_argument("--kv-dtype", default=None,
                    choices=["f32", "int8"],
                    help="engine mode: KV page-pool storage dtype — "
                         "int8 stores 1-byte K/V cells with per-page-"
                         "per-head f32 absmax scales (4x fewer KV "
                         "bytes, ~4x pages per byte budget, temp-0 "
                         "output unchanged; default: $ENGINE_KV_DTYPE "
                         "or f32)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="engine mode: ingest prompts longer than this "
                         "many tokens in chunk-sized pieces interleaved "
                         "with decode steps, so admitted requests never "
                         "stall behind a long prompt (0 = monolithic "
                         "prefill at admission)")
    ap.add_argument("--speculative", action="store_true",
                    help="engine mode: per-chain speculative decoding "
                         "(temp-0 output unchanged, fewer decode iters)")
    ap.add_argument("--drafter", default="ngram",
                    choices=["ngram", "radix"],
                    help="speculative mode: draft source — ngram "
                         "prompt-lookup or radix-cache continuation")
    ap.add_argument("--draft-len", type=int, default=4,
                    help="speculative mode: max draft tokens per stream "
                         "per step")
    ap.add_argument("--continuous", action="store_true",
                    help="engine mode: open-system continuous batching "
                         "with Poisson arrivals (vs one closed batch)")
    ap.add_argument("--arrival-rate", type=float, default=4.0,
                    help="continuous mode: Poisson arrival rate, req/s")
    ap.add_argument("--policy", default="fcfs",
                    choices=["fcfs", "chain-aware"],
                    help="continuous mode: admission policy")
    ap.add_argument("--requests", type=int, default=0,
                    help="engine mode: number of requests (default: "
                         "--batch, or every line of --prompts-file)")
    ap.add_argument("--plan-file", default=None,
                    help="engine mode: file with plan text to "
                         "teacher-force (replaces the built-in toy plan)")
    ap.add_argument("--prompts-file", default=None,
                    help="engine mode: file with one prompt per line "
                         "(replaces the built-in toy prompts)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="engine mode: record a structured trace and "
                         "write it to PATH (JSONL) plus a Chrome "
                         "trace-event twin for Perfetto; also prints "
                         "per-request DAG timelines")
    ap.add_argument("--audit-log", default=None, metavar="PATH",
                    help="engine mode: record the clinical audit trail "
                         "(per-decision verdicts + per-request "
                         "dispositions for stage-typed plans) and "
                         "write it to PATH (medverse-audit/1 JSONL)")
    ap.add_argument("--metrics", action="store_true",
                    help="engine mode: print the engine metrics "
                         "registry (Prometheus text format) after "
                         "the run")
    ap.add_argument("--metrics-port", type=int, default=None,
                    metavar="PORT",
                    help="engine mode: serve /metrics (Prometheus "
                         "text) and /healthz on 127.0.0.1:PORT for "
                         "the duration of the run (0 = ephemeral)")
    args = ap.parse_args()

    if args.engine:
        run_engine(args)
        return

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = (make_host_mesh() if args.host_mesh
            else make_production_mesh(multi_pod=args.multi_pod))
    daxes, maxis = mesh_axes(mesh)
    set_global_mesh(mesh)
    meshctx.set_mesh(mesh, daxes, maxis)
    print(f"mesh={dict(mesh.shape)} arch={cfg.name}")

    params = init_params(jax.random.PRNGKey(0), cfg)
    cache = init_cache(cfg, args.batch, args.max_len)
    pspecs = param_specs(cfg, params, mesh, fsdp=False)
    cspecs = cache_specs_tree(cfg, cache, mesh)
    step = jax.jit(
        lambda p, c, t, wi, qp: decode_step(p, c, t, wi, qp, cfg),
        in_shardings=as_shardings(mesh, (pspecs, cspecs, None, None, None)),
        out_shardings=as_shardings(mesh, (None, cspecs)),
        donate_argnums=(1,),
    )
    tok = jnp.zeros((args.batch,), jnp.int32)
    t0 = time.time()
    for i in range(args.steps):
        logits, cache = step(params, cache, tok, jnp.int32(i),
                             jnp.full((args.batch,), i, jnp.int32))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    dt = time.time() - t0
    print(f"{args.steps} steps x batch {args.batch}: "
          f"{args.steps*args.batch/dt:.1f} tok/s "
          f"({dt/args.steps*1e3:.1f} ms/step); sample token ids "
          f"{np.asarray(tok)[:4]}")


if __name__ == "__main__":
    main()
