import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines, before any other import: jax locks the
# device count at first init, and the dry-run needs 512 placeholder host
# devices to build the production meshes (16x16 single-pod; 2x16x16
# multi-pod). Never set this in conftest/pyproject — tests and benches
# must see 1 device.

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, get_config
from ..configs.shapes import InputShape, train_input_specs
from ..models import TopoBatch, decode_step, forward, init_cache, init_params
from ..models import meshctx
from ..train import AdamWConfig, init_opt_state, make_train_step
from .mesh import (as_shardings, make_production_mesh, mesh_axes,
                   set_global_mesh)
from .roofline import model_flops, parse_collectives, roofline_from_compiled
from .sharding import batch_specs, cache_specs_tree, opt_state_specs, param_specs

# long_500k applicability (DESIGN.md §4): sub-quadratic decode state only.
LONG_OK = {"gemma3-1b", "recurrentgemma-2b", "rwkv6-3b"}
_FSDP_OVERRIDE: Optional[bool] = None
_SEQ_SHARD = False
_SHARDED_OUT = False


def sds_tree(f, *args):
    return jax.eval_shape(f, *args)


def estimate_device_bytes(tree: Any, specs: Any, mesh) -> int:
    """Per-device bytes of a sharded pytree of ShapeDtypeStructs."""
    total = 0
    for leaf, spec in zip(jax.tree_util.tree_leaves(tree),
                          jax.tree_util.tree_leaves(
                              specs, is_leaf=lambda x: isinstance(x, P))):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        denom = 1
        for ax in tuple(spec):
            if ax is None:
                continue
            if isinstance(ax, tuple):
                for a in ax:
                    denom *= mesh.shape[a]
            else:
                denom *= mesh.shape[ax]
        total += (n // max(denom, 1)) * leaf.dtype.itemsize
    return total


def lower_train(cfg, shape: InputShape, mesh):
    """Lower a full train step (fwd + bwd + AdamW) for the mesh."""
    specs_in = train_input_specs(cfg, shape)
    params_sds = sds_tree(lambda: init_params(jax.random.PRNGKey(0), cfg))
    opt_sds = sds_tree(lambda: init_opt_state(params_sds))
    pspecs = param_specs(cfg, params_sds, mesh, fsdp=_FSDP_OVERRIDE)
    ospecs = opt_state_specs(cfg, pspecs)
    bspecs = batch_specs(cfg, specs_in, mesh, seq_shard=_SEQ_SHARD)
    step = make_train_step(cfg, AdamWConfig())

    jitted = jax.jit(
        step,
        in_shardings=as_shardings(mesh, (pspecs, ospecs, bspecs)),
        out_shardings=as_shardings(mesh, (pspecs, ospecs, None)),
        donate_argnums=(0, 1),
    )
    lowered = jitted.lower(params_sds, opt_sds, specs_in)
    arg_bytes = (
        estimate_device_bytes(params_sds, pspecs, mesh)
        + estimate_device_bytes(opt_sds["mu"], pspecs, mesh) * 2
        + estimate_device_bytes(specs_in, bspecs, mesh)
    )
    n_tokens = shape.global_batch * shape.seq_len
    return lowered, arg_bytes, n_tokens, "train"


def lower_prefill(cfg, shape: InputShape, mesh):
    """Prefill: full-sequence forward producing logits (inference)."""
    b, s = shape.global_batch, shape.seq_len
    specs_in = train_input_specs(cfg, shape)
    specs_in.pop("targets")
    specs_in.pop("loss_mask")
    params_sds = sds_tree(lambda: init_params(jax.random.PRNGKey(0), cfg))
    pspecs = param_specs(cfg, params_sds, mesh, fsdp=False)
    bspecs = batch_specs(cfg, specs_in, mesh, seq_shard=_SEQ_SHARD)

    def prefill_step(params, batch):
        topo = TopoBatch(seg_id=batch["seg_id"], layer_id=batch["layer_id"],
                         pos_id=batch["pos_id"])
        kw = {}
        if cfg.vision is not None and "image_embeds" in batch:
            kw["image_embeds"] = batch["image_embeds"]
        if cfg.encoder is not None and "audio_embeds" in batch:
            kw["audio_embeds"] = batch["audio_embeds"]
        logits, _ = forward(params, batch["tokens"], topo, cfg, **kw)
        return logits

    daxes_p, _ = mesh_axes(mesh)
    out_spec = (P(daxes_p, "model" if _SEQ_SHARD else None, "model")
                if False else P(daxes_p, None, "model"))
    jitted = jax.jit(prefill_step,
                     in_shardings=as_shardings(mesh, (pspecs, bspecs)),
                     out_shardings=as_shardings(
                         mesh, out_spec if _SHARDED_OUT else None))
    lowered = jitted.lower(params_sds, specs_in)
    arg_bytes = (estimate_device_bytes(params_sds, pspecs, mesh)
                 + estimate_device_bytes(specs_in, bspecs, mesh))
    return lowered, arg_bytes, shape.global_batch * shape.seq_len, "prefill"


def lower_decode(cfg, shape: InputShape, mesh):
    """serve_step: ONE new token against a KV cache of seq_len."""
    b, s = shape.global_batch, shape.seq_len
    params_sds = sds_tree(lambda: init_params(jax.random.PRNGKey(0), cfg))
    cache_sds = sds_tree(lambda: init_cache(cfg, b, s))
    pspecs = param_specs(cfg, params_sds, mesh, fsdp=False)
    cspecs = cache_specs_tree(cfg, cache_sds, mesh)
    daxes, _ = mesh_axes(mesh)
    import numpy as _np
    dsize = int(_np.prod([mesh.shape[a] for a in daxes]))
    tok_spec = P(daxes) if b % dsize == 0 and b > 1 else P()

    def serve_step(params, cache, token_t, write_index, q_pos):
        return decode_step(params, cache, token_t, write_index, q_pos, cfg)

    jitted = jax.jit(
        serve_step,
        in_shardings=as_shardings(
            mesh, (pspecs, cspecs, tok_spec, None, tok_spec)),
        out_shardings=as_shardings(mesh, (None, cspecs)),
        donate_argnums=(1,),
    )
    tok_sds = jax.ShapeDtypeStruct((b,), jnp.int32)
    wi_sds = jax.ShapeDtypeStruct((), jnp.int32)
    lowered = jitted.lower(params_sds, cache_sds, tok_sds, wi_sds, tok_sds)
    arg_bytes = (estimate_device_bytes(params_sds, pspecs, mesh)
                 + estimate_device_bytes(cache_sds, cspecs, mesh))
    return lowered, arg_bytes, b, "decode"


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: Optional[str] = None, verbose: bool = True,
            no_scan: bool = False, attn_impl: Optional[str] = None,
            remat: Optional[bool] = None, fsdp: Optional[str] = None,
            seq_shard: bool = False, sharded_out: bool = False,
            tag: str = "") -> Dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    daxes, maxis = mesh_axes(mesh)
    set_global_mesh(mesh)
    meshctx.set_mesh(mesh, daxes, maxis)
    n_chips = mesh.size
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips, "status": "unknown",
    }
    if shape_name == "long_500k" and arch not in LONG_OK:
        rec["status"] = "skipped"
        rec["reason"] = "full-attention arch; long_500k skip per DESIGN.md §4"
        return _emit(rec, out_dir, verbose)
    if shape.kind == "decode" and cfg.max_seq_len < shape.seq_len:
        cfg = __import__("dataclasses").replace(cfg, max_seq_len=shape.seq_len)
    if no_scan:
        # Unrolled layers: XLA cost_analysis counts a lax.scan body ONCE
        # regardless of trip count, so the roofline pass unrolls to get
        # honest per-device FLOP/byte totals (see EXPERIMENTS.md §Dry-run).
        cfg = __import__("dataclasses").replace(cfg, scan_layers=False)
        rec["unrolled"] = True
    # §Perf hillclimb knobs (EXPERIMENTS.md records these per iteration)
    if attn_impl:
        cfg = __import__("dataclasses").replace(cfg, attn_impl=attn_impl)
        rec["attn_impl"] = attn_impl
    if remat is not None:
        cfg = __import__("dataclasses").replace(cfg, remat=remat)
        rec["remat"] = remat
    if fsdp in ("on", "off"):
        global _FSDP_OVERRIDE
        _FSDP_OVERRIDE = fsdp == "on"
        rec["fsdp"] = fsdp
    if seq_shard:
        global _SEQ_SHARD
        _SEQ_SHARD = True
        rec["seq_shard"] = True
    if sharded_out:
        global _SHARDED_OUT
        _SHARDED_OUT = True
        rec["sharded_out"] = True
    if tag:
        rec["tag"] = tag
    t0 = time.time()
    try:
        if shape.kind == "train":
            lowered, arg_bytes, n_tokens, kind = lower_train(cfg, shape, mesh)
        elif shape.kind == "prefill":
            lowered, arg_bytes, n_tokens, kind = lower_prefill(cfg, shape, mesh)
        else:
            lowered, arg_bytes, n_tokens, kind = lower_decode(cfg, shape, mesh)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(ma, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, k)
            }
        except Exception as e:  # CPU backend may not implement it
            rec["memory_analysis"] = f"unavailable: {e}"
        rec["arg_bytes_per_device_est"] = int(arg_bytes)
        hlo = compiled.as_text()
        roof, coll = roofline_from_compiled(compiled, n_chips, hlo)
        rec["roofline"] = roof.as_dict()
        rec["collectives"] = {
            "bytes_by_kind": coll.bytes_by_kind,
            "count_by_kind": coll.count_by_kind,
        }
        mf = model_flops(cfg, n_tokens, "train" if kind == "train" else "serve")
        rec["model_flops_global"] = mf
        hlo_flops_global = roof.flops_per_device * n_chips
        rec["useful_flops_ratio"] = (
            mf / hlo_flops_global if hlo_flops_global else None
        )
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return _emit(rec, out_dir, verbose)


def _emit(rec: Dict, out_dir: Optional[str], verbose: bool) -> Dict:
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = "__unrolled" if rec.get("unrolled") else ""
        if rec.get("tag"):
            suffix += f"__{rec['tag']}"
        fn = (f"{rec['arch']}__{rec['shape']}__"
              f"{rec['mesh'].replace('x','_')}{suffix}.json")
        with open(os.path.join(out_dir, fn), "w") as f:
            json.dump(rec, f, indent=2, default=str)
    if verbose:
        brief = {k: v for k, v in rec.items() if k != "traceback"}
        print(json.dumps(brief, indent=2, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser(description="MedVerse multi-pod dry-run")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-scan", action="store_true",
                    help="unroll layer scans for honest cost_analysis")
    ap.add_argument("--attn-impl", default=None,
                    choices=["naive", "chunked"])
    ap.add_argument("--remat", default=None, choices=["on", "off"])
    ap.add_argument("--fsdp", default=None, choices=["on", "off"])
    ap.add_argument("--seq-shard", action="store_true",
                    help="shard sequence dim over model axis (TP+SP)")
    ap.add_argument("--sharded-out", action="store_true",
                    help="keep prefill logits vocab-sharded on output")
    ap.add_argument("--tag", default="",
                    help="suffix for the output json (perf iterations)")
    args = ap.parse_args()
    rec = run_one(args.arch, args.shape, args.multi_pod, args.out,
                  no_scan=args.no_scan, attn_impl=args.attn_impl,
                  remat=None if args.remat is None else args.remat == "on",
                  fsdp=args.fsdp, seq_shard=args.seq_shard,
                  sharded_out=args.sharded_out, tag=args.tag)
    raise SystemExit(0 if rec["status"] in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
