"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw_per_chip
    collective = collective_bytes_per_device / ICI_bw_per_chip

HLO FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device after
SPMD partitioning). Collective bytes are parsed out of the optimized HLO
text: we sum output sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed shape literal in ``text``."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # "%x = TYPE[dims] all-reduce(...)" or fusion-wrapped "-start" ops
        m = re.search(r"=\s+(.*?)\s+([\w-]+)\(", s)
        if not m:
            continue
        op = m.group(2)
        base = op.replace("-start", "").replace("-done", "")
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        out_shape = m.group(1)
        by_kind[base] += _shape_bytes(out_shape)
        count[base] += 1
    return CollectiveStats(bytes_by_kind=by_kind, count_by_kind=count)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    n_chips: int
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""

    def finalize(self) -> "Roofline":
        self.compute_s = self.flops_per_device / PEAK_FLOPS
        self.memory_s = self.bytes_per_device / HBM_BW
        self.collective_s = self.collective_bytes / ICI_BW
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        self.dominant = max(terms, key=terms.get)
        return self

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline_from_compiled(compiled, n_chips: int,
                           hlo_text: Optional[str] = None) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text)
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes=float(coll.total_bytes),
        n_chips=n_chips,
    ).finalize(), coll


def model_flops(cfg, n_tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference), N = active params."""
    n = cfg.param_count(active_only=True)
    per_tok = 6 * n if kind == "train" else 2 * n
    return float(per_tok) * n_tokens
