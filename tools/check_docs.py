"""Docs consistency check (CI `docs` job; stdlib only).

Validates, for every markdown file in ``docs/`` plus ``README.md``:

1. **Relative links** ``[text](path)`` resolve to files/directories in
   the repo (external ``http(s)://`` and ``#anchor``-only links are
   skipped; a ``path#anchor`` suffix is stripped before checking).
2. **Path-like code spans** — an inline ``code`` span that looks like a
   repo path (starts with a known top-level directory and contains a
   ``/``) must exist.
3. **Module references** — an inline code span like
   ``repro.engine.spec`` must resolve to a module file under ``src/``
   (``src/repro/engine/spec.py`` or a package ``__init__.py``); a
   dotted suffix beyond the deepest module (``repro.engine.spec.
   Drafter.propose``) must appear as a name in that module's source.

Exit code 1 with one line per violation; 0 when clean.
"""

from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# top-level dirs a `code` span may point into to count as a path claim.
# results/ is deliberately absent: docs cite bench *output* paths
# (results/BENCH_spec.json) that only exist after a run.
PATH_ROOTS = ("src/", "benchmarks/", "tests/", "docs/", "tools/",
              "examples/", ".github/")

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_RE = re.compile(r"`([^`\n]+)`")
MODULE_RE = re.compile(r"^(repro(?:\.\w+)+)")


def _doc_files():
    files = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        for root, _, names in os.walk(docs):
            files.extend(os.path.join(root, n) for n in names
                         if n.endswith(".md"))
    return [f for f in files if os.path.exists(f)]


def _strip_code_blocks(text: str) -> str:
    """Drop fenced code blocks: links/paths inside them are examples
    (shell output, diagrams), not claims about the tree."""
    return re.sub(r"```.*?```", "", text, flags=re.S)


def _check_link(base_dir: str, target: str):
    if target.startswith(("http://", "https://", "mailto:", "#")):
        return None
    path = target.split("#", 1)[0]
    if not path:
        return None
    if path.startswith("/"):
        return f"absolute link {target!r} (use a relative path)"
    resolved = os.path.normpath(os.path.join(base_dir, path))
    if not os.path.exists(resolved):
        return f"broken link {target!r}"
    return None


def _check_module(span: str):
    """`repro.x.y[.Name...]` -> error string or None."""
    m = MODULE_RE.match(span)
    if m is None:
        return None
    dotted = m.group(1).split(".")
    # longest prefix that is a module file or package
    mod_file, consumed = None, 0
    for i in range(len(dotted), 0, -1):
        stem = os.path.join(REPO, "src", *dotted[:i])
        for cand in (stem + ".py", os.path.join(stem, "__init__.py")):
            if os.path.exists(cand):
                mod_file, consumed = cand, i
                break
        if mod_file:
            break
    if mod_file is None:
        return f"module {'.'.join(dotted)!r} not found under src/"
    leftover = dotted[consumed:]
    if leftover:
        with open(mod_file) as f:
            source = f.read()
        for name in leftover:
            if not re.search(rf"\b{re.escape(name)}\b", source):
                return (f"{'.'.join(dotted)!r}: name {name!r} not found "
                        f"in {os.path.relpath(mod_file, REPO)}")
    return None


def _check_path_span(span: str):
    # strip a trailing :line or wildcard; only bare path claims checked
    path = span.split(":")[0].split("#")[0]
    if not path.startswith(PATH_ROOTS) and path not in (
            p.rstrip("/") for p in PATH_ROOTS):
        return None
    if any(ch in path for ch in "*{}<>$ "):
        return None            # glob / placeholder, not a path claim
    if not os.path.exists(os.path.join(REPO, path)):
        return f"path {span!r} does not exist"
    return None


def main() -> int:
    errors = []
    for fpath in _doc_files():
        rel = os.path.relpath(fpath, REPO)
        with open(fpath) as f:
            text = _strip_code_blocks(f.read())
        base_dir = os.path.dirname(fpath)
        for target in LINK_RE.findall(text):
            err = _check_link(base_dir, target)
            if err:
                errors.append(f"{rel}: {err}")
        for span in CODE_RE.findall(text):
            err = _check_path_span(span) or _check_module(span)
            if err:
                errors.append(f"{rel}: {err}")
    if errors:
        print(f"check_docs: {len(errors)} problem(s)")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"check_docs: {len(_doc_files())} file(s) clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
