#!/usr/bin/env python3
"""Offline cost/time attribution for engine traces (``medverse-trace/1``).

Stdlib-only (CI-safe, no repo imports). Reads the JSONL trace that
``MedVerseEngine.dump_trace`` / ``serve.py --trace`` /
``benchmarks/serving_bench.py`` write and renders the analytic cost
model's counter tracks (``cost_*``, emitted by ``repro.obs.cost``) plus
the X-span wall times into a per-phase attribution table::

    python tools/trace_view.py results/serving_trace.jsonl

    phase        steps    time_s   attn_flops    kv_read_b   kv_write_b
    prefill          2  0.012345     16777216            0       294912
    decode          81  0.456789     47900672     47900672       497664
    spec_verify      0         -            0            0            0
    ...

Two attribution sources, deliberately separate: *cost* columns come
from the deterministic counter series (machine-independent integers —
what CI gates), *time* columns from X-span durations (wall clock —
machine-dependent, never gated). ``spec_verify`` rows run inside the
batched decode dispatch, so their wall time is included in ``decode``
and shown as ``-``.

``--diff A.jsonl B.jsonl`` compares two traces (e.g. before/after a
perf change) and reports deltas in steps, FLOPs, KV bytes, padding
waste, page gathers, compiles/recompiles, and event counts.

Exit 0 always for readable traces; exit 1 on unreadable/absent input.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple

SCHEMA = "medverse-trace/1"
PHASES = ("prefill", "decode", "spec_verify")


def load(path: str) -> Tuple[dict, List[dict]]:
    with open(path) as f:
        lines = [json.loads(ln) for ln in f if ln.strip()]
    if not lines or lines[0].get("schema") != SCHEMA:
        raise ValueError(f"{path}: not a {SCHEMA} trace file")
    return lines[0], lines[1:]


def analyze(header: dict, events: List[dict]) -> dict:
    """Reduce a trace to the attribution numbers the renderers use.

    Cost counters are cumulative, so the *last* sample of each series
    is its lifetime total; wall time per phase is the sum of matching
    X-span durations.
    """
    counters: Dict[str, dict] = {}     # name -> last values dict
    span_time: Dict[str, float] = {}   # X name -> summed dur
    span_count: Dict[str, int] = {}
    compiles = 0
    compiles_after_warmup = 0
    warmup_step = header.get("meta", {}).get("warmup_step")
    n_requests = 0
    final_step = 0
    for ev in events:
        ph = ev.get("ph")
        final_step = max(final_step, ev.get("step", 0))
        if ph == "C":
            counters[ev.get("name", "")] = ev.get("values", {})
        elif ph == "X":
            name = ev.get("name", "")
            span_time[name] = span_time.get(name, 0.0) + ev.get("dur", 0.0)
            span_count[name] = span_count.get(name, 0) + 1
            if name == "compile":
                compiles += 1
                after = ev.get("args", {}).get("after_warmup")
                if after or (after is None and warmup_step is not None
                             and ev.get("step", 0) > warmup_step):
                    compiles_after_warmup += 1
        elif ph == "B" and ev.get("name") == "request":
            n_requests += 1

    flops = counters.get("cost_attn_flops", {})
    kv = counters.get("cost_kv_bytes", {})
    pad = counters.get("cost_padding", {})
    pages = counters.get("cost_pages", {})
    useful = pad.get("useful_kv", 0)
    padded = pad.get("padded_kv", 0)
    return {
        # traces recorded with cost_accounting=False carry no cost_*
        # counter tracks at all — the renderers fall back to wall-time /
        # step attribution instead of printing misleading zeros
        "has_cost": any(n.startswith("cost_") for n in counters),
        "n_events": len(events),
        "n_requests": n_requests,
        "final_step": final_step,
        "steps": {"prefill": span_count.get("prefill", 0),
                  "decode": span_count.get("decode", 0),
                  "spec_verify": None},
        "time_s": {"prefill": span_time.get("prefill"),
                   "decode": span_time.get("decode"),
                   "spec_verify": None},
        "attn_flops": {ph: flops.get(ph, 0) for ph in PHASES},
        "kv_read_bytes": kv.get("read", 0),
        "kv_write_bytes": kv.get("written", 0),
        "useful_kv": useful,
        "padded_kv": padded,
        "padded_rows": pad.get("padded_rows", 0),
        "waste_ratio": padded / (useful + padded) if useful + padded else 0.0,
        "page_gathers": pages.get("gathers", 0),
        "compiles": compiles,
        "compiles_after_warmup": compiles_after_warmup,
        "compile_time_s": span_time.get("compile", 0.0),
        "warmup_step": warmup_step,
    }


def _fmt(v, width: int) -> str:
    if v is None:
        return "-".rjust(width)
    if isinstance(v, float):
        return f"{v:.6f}".rjust(width)
    return f"{v:,}".rjust(width)


NO_COST_NOTE = ("note: no cost_* counter tracks in this trace (recorded "
                "with cost_accounting=False); showing step/time "
                "attribution only")


def render(path: str, a: dict) -> str:
    lines = [f"{path}: {a['n_events']} events, {a['n_requests']} requests, "
             f"final step {a['final_step']}"]
    if not a["has_cost"]:
        lines.append(NO_COST_NOTE)
    cols = ("phase", "steps", "time_s", "attn_flops")
    widths = (12, 8, 12, 18)
    if not a["has_cost"]:
        cols, widths = cols[:3], widths[:3]
    lines.append("".join(c.rjust(w) if i else c.ljust(w)
                         for i, (c, w) in enumerate(zip(cols, widths))))
    for ph in PHASES:
        row = (ph, a["steps"][ph], a["time_s"][ph], a["attn_flops"][ph])
        lines.append(row[0].ljust(widths[0])
                     + "".join(_fmt(v, w) for v, w in
                               zip(row[1:len(cols)], widths[1:])))
    if a["has_cost"]:
        total_flops = sum(a["attn_flops"][ph] for ph in PHASES)
        lines.append("total".ljust(widths[0])
                     + _fmt(None, widths[1]) + _fmt(None, widths[2])
                     + _fmt(total_flops, widths[3]))
        lines.append("")
        lines.append(f"kv bytes: read {a['kv_read_bytes']:,}  "
                     f"written {a['kv_write_bytes']:,}")
        lines.append(f"padding:  useful_kv {a['useful_kv']:,}  "
                     f"padded_kv {a['padded_kv']:,}  "
                     f"waste {a['waste_ratio']:.1%}  "
                     f"padded_rows {a['padded_rows']:,}")
        lines.append(f"pages:    gathers {a['page_gathers']:,}")
    else:
        lines.append("")
    warm = (f" (warmup ended step {a['warmup_step']})"
            if a["warmup_step"] is not None else "")
    lines.append(f"compiles: {a['compiles']} "
                 f"({a['compile_time_s']:.3f}s), "
                 f"after warmup {a['compiles_after_warmup']}{warm}")
    return "\n".join(lines)


# (label, getter, needs_cost) — cost rows only render when both traces
# carry the cost_* counter tracks
_DIFF_FIELDS = (
    ("decode steps", lambda a: a["steps"]["decode"], False),
    ("prefills", lambda a: a["steps"]["prefill"], False),
    ("attn_flops total",
     lambda a: sum(a["attn_flops"][p] for p in PHASES), True),
    ("attn_flops prefill", lambda a: a["attn_flops"]["prefill"], True),
    ("attn_flops decode", lambda a: a["attn_flops"]["decode"], True),
    ("attn_flops spec_verify",
     lambda a: a["attn_flops"]["spec_verify"], True),
    ("kv_read_bytes", lambda a: a["kv_read_bytes"], True),
    ("kv_write_bytes", lambda a: a["kv_write_bytes"], True),
    ("useful_kv", lambda a: a["useful_kv"], True),
    ("padded_kv", lambda a: a["padded_kv"], True),
    ("padded_rows", lambda a: a["padded_rows"], True),
    ("page_gathers", lambda a: a["page_gathers"], True),
    ("compiles", lambda a: a["compiles"], False),
    ("recompiles after warmup", lambda a: a["compiles_after_warmup"], False),
    ("events", lambda a: a["n_events"], False),
)


def render_diff(pa: str, a: dict, pb: str, b: dict) -> str:
    lines = [f"diff: {pa} -> {pb}"]
    both_cost = a["has_cost"] and b["has_cost"]
    if not both_cost:
        missing = [p for p, x in ((pa, a), (pb, b)) if not x["has_cost"]]
        lines.append(f"note: no cost_* counter tracks in "
                     f"{' and '.join(missing)} (cost_accounting=False); "
                     f"diffing steps/time only")
    lines.append(f"{'metric':<24}{'a':>16}{'b':>16}{'delta':>16}  rel")
    for label, get, needs_cost in _DIFF_FIELDS:
        if needs_cost and not both_cost:
            continue
        va, vb = get(a), get(b)
        d = vb - va
        rel = f"{d / va:+.1%}" if va else ("n/a" if d else "0%")
        mark = "" if d == 0 else "  <-- changed"
        lines.append(f"{label:<24}{va:>16,}{vb:>16,}{d:>+16,}  "
                     f"{rel}{mark}")
    if both_cost:
        wa, wb = a["waste_ratio"], b["waste_ratio"]
        lines.append(f"{'padding waste ratio':<24}{wa:>16.4f}{wb:>16.4f}"
                     f"{wb - wa:>+16.4f}")
    return "\n".join(lines)


def main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(
        description="per-phase cost/time attribution for engine traces")
    ap.add_argument("trace", nargs="?", help="trace JSONL to render")
    ap.add_argument("--diff", nargs=2, metavar=("A", "B"),
                    help="compare two trace JSONL files instead")
    args = ap.parse_args(argv)
    if args.diff:
        try:
            ha, ea = load(args.diff[0])
            hb, eb = load(args.diff[1])
        except (OSError, ValueError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 1
        print(render_diff(args.diff[0], analyze(ha, ea),
                          args.diff[1], analyze(hb, eb)))
        return 0
    if not args.trace:
        ap.print_usage()
        return 1
    try:
        header, events = load(args.trace)
    except (OSError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    print(render(args.trace, analyze(header, events)))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
