#!/usr/bin/env python3
"""Validate a recorded engine trace file (``medverse-trace/1`` JSONL).

Stdlib-only (CI-safe, no repo imports) structural checker for the
traces ``MedVerseEngine.dump_trace`` / ``serve.py --trace`` /
``benchmarks/serving_bench.py`` write:

* header line present with the expected ``schema`` tag;
* every event is well-formed: known phase (``B E I X C``), a name and
  category, ``ts`` (wall seconds, >= 0 and non-decreasing per emission
  order is NOT required — ``X`` events backdate to their start), and a
  ``step`` clock value that never decreases across events;
* every ``B`` span is closed by a matching ``E`` on its ``(rid,
  track)`` lane, LIFO per lane, none left open at EOF;
* counter series are step-monotone per series name, and the cumulative
  analytic-cost series (``cost_*``) additionally never decrease in
  value;
* when the warmup ladder ran (``meta.warmup_step`` present), every
  ``compile`` X-span sits at a step <= that boundary — the engine's
  "no recompiles after warmup" invariant, checkable offline;
* cross-references resolve: every ``rid`` carried by a stream/spec
  event belongs to a request whose ``request`` span was opened; every
  ``page`` id in a kvcache event lies inside the pool recorded in the
  header (``meta.n_pages``);
* ``X`` events carry a non-negative ``dur``;
* audit events (``cat="audit"``, emitted when ``EngineConfig.audit`` is
  on) are instants landing inside their request's open span, decision
  events reference a stream track the request actually ran and carry a
  stage/status from the closed vocabularies, and every audited request
  that finished (completed or aborted — not one that ended the trace
  preempted) carries its final disposition exactly once;
* the header stamps the KV pool storage dtype (``meta.kv_dtype``, one
  of ``f32``/``int8``) so a trace is attributable to its matrix leg;
* chunked-prefill spans (``prefill_chunk`` X events, emitted when
  ``EngineConfig.prefill_chunk`` > 0) are consistent per request:
  within one ingestion episode the ``seq`` numbers are dense from 0,
  the ``offset`` of each chunk continues exactly where the previous
  one ended (starting at the radix-cached prefix length), the emission
  steps strictly increase (chunks genuinely interleave with decode
  steps), and for a request that closed normally the chunk rows sum to
  the uncached prompt length. A preemption restarts ingestion (``seq``
  resets to 0 on re-admission), which splits episodes.

Standalone audit files (``medverse-audit/1`` JSONL, written by
``MedVerseEngine.dump_audit`` / ``serve.py --audit-log``) are detected
by their header schema and get their own structural checks: known
record kinds, closed verdict/disposition/stage vocabularies, a
non-decreasing step clock, and exactly one disposition per request.

Usage::

    python tools/check_trace.py results/serving_trace.jsonl [more...]
    python tools/check_trace.py results/serving_audit.jsonl

Exit 0 and a one-line summary per file when clean; exit 1 with every
problem listed otherwise. A sibling ``*.chrome.json`` export, when
present, is additionally checked to parse as Chrome trace-event JSON
with a non-empty ``traceEvents`` list.
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional, Tuple

SCHEMA = "medverse-trace/1"
AUDIT_SCHEMA = "medverse-audit/1"
PHASES = ("B", "E", "I", "X", "C")
# closed vocabularies mirroring repro.obs.audit (stdlib-only: no import)
DECISION_STAGES = ("critic", "guardrail")
VERDICT_STATUSES = ("pass", "fail", "abstain")
DISPOSITIONS = ("verified", "refuted", "unverified")


def load(path: str) -> Tuple[dict, List[dict]]:
    with open(path) as f:
        lines = [json.loads(ln) for ln in f if ln.strip()]
    if not lines:
        raise ValueError("empty file")
    header, events = lines[0], lines[1:]
    if header.get("schema") not in (SCHEMA, AUDIT_SCHEMA):
        raise ValueError(
            f"bad header schema: {header.get('schema')!r} "
            f"(want {SCHEMA!r} or {AUDIT_SCHEMA!r})")
    return header, events


def check_events(header: dict, events: List[dict]) -> List[str]:
    problems: List[str] = []
    meta = header.get("meta", {})
    n_pages: Optional[int] = meta.get("n_pages")
    warmup_step: Optional[int] = meta.get("warmup_step")
    if meta.get("kv_dtype") not in ("f32", "int8"):
        problems.append(
            f"header meta.kv_dtype {meta.get('kv_dtype')!r} "
            f"(want 'f32' or 'int8' — engine traces stamp the KV pool "
            f"storage dtype)")
    open_spans: Dict[tuple, List[str]] = {}
    requests_seen = set()
    # audit cross-ref state: request spans currently open, the stream
    # tracks each request ran, disposition counts, and how each rid's
    # request span last ended (completed / "aborted" / "preempted")
    requests_open = set()
    stream_tracks: Dict[int, set] = {}
    disposition_count: Dict[int, int] = {}
    rids_with_decisions = set()
    last_end_reason: Dict[int, Optional[str]] = {}
    last_step = -1
    # per counter-series state: last step and (cost_* only) last values
    counter_step: Dict[str, int] = {}
    counter_vals: Dict[str, dict] = {}
    # chunked-prefill ingestion spans per rid, in emission order
    chunk_spans: Dict[int, List[Tuple[int, int, dict]]] = {}
    for i, ev in enumerate(events):
        where = f"event {i}"
        ph = ev.get("ph")
        if ph not in PHASES:
            problems.append(f"{where}: unknown phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            problems.append(f"{where}: missing name")
        if not isinstance(ev.get("cat"), str) or not ev["cat"]:
            problems.append(f"{where}: missing cat")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"{where}: bad ts {ts!r}")
        step = ev.get("step")
        if not isinstance(step, int) or step < 0:
            problems.append(f"{where}: bad step {step!r}")
        else:
            if step < last_step:
                problems.append(
                    f"{where}: step clock went backwards "
                    f"({last_step} -> {step})")
            last_step = max(last_step, step)
        if ph == "X" and not (isinstance(ev.get("dur"), (int, float))
                              and ev["dur"] >= 0):
            problems.append(f"{where}: X without non-negative dur")
        if ph == "C":
            vals = ev.get("values")
            if not isinstance(vals, dict):
                problems.append(f"{where}: C without values dict")
            else:
                name_c = ev.get("name", "")
                if isinstance(step, int):
                    prev = counter_step.get(name_c, -1)
                    if step < prev:
                        problems.append(
                            f"{where}: counter {name_c!r} series went "
                            f"backwards in step ({prev} -> {step})")
                    counter_step[name_c] = max(prev, step)
                if name_c.startswith("cost_"):
                    prev_vals = counter_vals.get(name_c, {})
                    for k, v in vals.items():
                        pv = prev_vals.get(k)
                        if (pv is not None
                                and isinstance(v, (int, float))
                                and v < pv):
                            problems.append(
                                f"{where}: cumulative counter "
                                f"{name_c!r}[{k!r}] decreased "
                                f"({pv} -> {v})")
                    counter_vals[name_c] = dict(vals)
        if (ph == "X" and ev.get("name") == "compile"
                and warmup_step is not None
                and isinstance(step, int) and step > warmup_step):
            problems.append(
                f"{where}: compile span at step {step} after the "
                f"warmup ladder finished (meta.warmup_step="
                f"{warmup_step})")
        rid = ev.get("rid")
        name = ev["name"] if isinstance(ev.get("name"), str) else ""
        # request lifecycle / cross-refs
        if ph == "B" and name == "request":
            requests_seen.add(rid)
            requests_open.add(rid)
        elif ph == "E" and name == "request":
            requests_open.discard(rid)
            last_end_reason[rid] = ev.get("args", {}).get("reason")
        elif rid is not None and ev.get("cat") in ("stream", "spec"):
            if rid not in requests_seen:
                problems.append(
                    f"{where}: {name} references rid={rid} with no "
                    f"request span opened")
        if (ph == "B" and name == "stream"
                and ev.get("track") is not None):
            stream_tracks.setdefault(rid, set()).add(ev["track"])
        # audit events: instants inside the request's open span, closed
        # vocabularies, decisions cross-referencing a real stream track
        if ev.get("cat") == "audit":
            if ph != "I":
                problems.append(f"{where}: audit event with phase "
                                f"{ph!r} (want I)")
            if rid not in requests_open:
                problems.append(
                    f"{where}: audit {name!r} for rid={rid} outside "
                    f"any open request span")
            args = ev.get("args", {})
            if name == "audit":
                rids_with_decisions.add(rid)
                if args.get("stage") not in DECISION_STAGES:
                    problems.append(
                        f"{where}: audit decision with stage "
                        f"{args.get('stage')!r} (want one of "
                        f"{DECISION_STAGES})")
                if args.get("status") not in VERDICT_STATUSES:
                    problems.append(
                        f"{where}: audit decision with status "
                        f"{args.get('status')!r} (want one of "
                        f"{VERDICT_STATUSES})")
                track = ev.get("track")
                if track not in stream_tracks.get(rid, ()):
                    problems.append(
                        f"{where}: audit decision references stream "
                        f"track {track!r} rid={rid} that never opened")
            elif name == "audit_disposition":
                if args.get("disposition") not in DISPOSITIONS:
                    problems.append(
                        f"{where}: disposition "
                        f"{args.get('disposition')!r} (want one of "
                        f"{DISPOSITIONS})")
                disposition_count[rid] = disposition_count.get(rid, 0) + 1
            else:
                problems.append(
                    f"{where}: unknown audit event name {name!r}")
        if (ph == "X" and name == "prefill_chunk"
                and isinstance(rid, int) and isinstance(step, int)):
            chunk_spans.setdefault(rid, []).append(
                (i, step, ev.get("args", {})))
        page = ev.get("args", {}).get("page")
        if page is not None and n_pages is not None:
            if not (isinstance(page, int) and 0 <= page < n_pages):
                problems.append(
                    f"{where}: page id {page!r} outside pool "
                    f"[0, {n_pages})")
        # span matching, LIFO per (rid, track) lane
        if ph in ("B", "E"):
            lane = (rid, ev.get("track"))
            stack = open_spans.setdefault(lane, [])
            if ph == "B":
                stack.append(name)
            elif not stack:
                problems.append(
                    f"{where}: E {name!r} on lane {lane} with no open "
                    f"span")
            elif stack[-1] != name:
                problems.append(
                    f"{where}: E {name!r} closes {stack[-1]!r} on lane "
                    f"{lane}")
                stack.pop()
            else:
                stack.pop()
    for lane, stack in open_spans.items():
        for name in stack:
            problems.append(f"span {name!r} on lane {lane} never closed")
    # every audited request that finished (its last request span did not
    # end in preemption) must carry its disposition exactly once; a
    # preempted-then-readmitted request legitimately re-emits decision
    # instants, but never a second disposition
    for rid, n in disposition_count.items():
        if n > 1:
            problems.append(
                f"rid={rid} carries {n} audit dispositions (want 1)")
    for rid in sorted(rids_with_decisions):
        if (disposition_count.get(rid, 0) == 0
                and last_end_reason.get(rid) != "preempted"):
            problems.append(
                f"rid={rid} has audit decisions but no final "
                f"disposition")
    # chunked-prefill span consistency per request. A preemption
    # restarts ingestion on re-admission (seq resets to 0), so the
    # span list splits into episodes validated independently.
    for rid, spans in sorted(chunk_spans.items()):
        episodes: List[List[Tuple[int, int, dict]]] = []
        for item in spans:
            if item[2].get("seq") == 0 or not episodes:
                episodes.append([])
            episodes[-1].append(item)
        for ep in episodes:
            prev_step = None
            expect_off = ep[0][2].get("n_cached")
            for want_seq, (idx, step, args) in enumerate(ep):
                where = f"event {idx}"
                if args.get("seq") != want_seq:
                    problems.append(
                        f"{where}: prefill_chunk rid={rid} seq "
                        f"{args.get('seq')!r} (want {want_seq} — chunk "
                        f"sequence must be dense per ingestion episode)")
                if args.get("offset") != expect_off:
                    problems.append(
                        f"{where}: prefill_chunk rid={rid} offset "
                        f"{args.get('offset')!r} (want {expect_off} — "
                        f"chunks must continue where the previous one "
                        f"ended)")
                n_rows = args.get("n_rows")
                if not isinstance(n_rows, int) or n_rows < 1:
                    problems.append(
                        f"{where}: prefill_chunk rid={rid} bad n_rows "
                        f"{n_rows!r}")
                    n_rows = 0
                if isinstance(args.get("offset"), int):
                    expect_off = args["offset"] + n_rows
                if prev_step is not None and step <= prev_step:
                    problems.append(
                        f"{where}: prefill_chunk rid={rid} at step "
                        f"{step} not after the previous chunk's step "
                        f"{prev_step} (chunks must interleave with "
                        f"decode steps)")
                prev_step = step
        # a request that closed normally (its last request span ended
        # without an abort/preempt reason) must have ingested exactly
        # the uncached prompt suffix in its final episode
        if rid not in requests_open and last_end_reason.get(rid) is None:
            last = episodes[-1]
            total = sum(a.get("n_rows") or 0 for _, _, a in last)
            a0 = last[0][2]
            want = (a0.get("n_prompt") or 0) - (a0.get("n_cached") or 0)
            if total != want:
                problems.append(
                    f"rid={rid}: prefill_chunk rows sum to {total}, "
                    f"want n_prompt - n_cached = {want} — a half-"
                    f"ingested prompt leaked into a completed request")
    return problems


def check_audit_records(records: List[dict]) -> List[str]:
    """Structural checks for a ``medverse-audit/1`` record list."""
    problems: List[str] = []
    last_step = -1
    disposition_count: Dict[int, int] = {}
    rids = set()
    for i, rec in enumerate(records):
        where = f"record {i}"
        kind = rec.get("kind")
        rid = rec.get("rid")
        if not isinstance(rid, int) or rid < 0:
            problems.append(f"{where}: bad rid {rid!r}")
            continue
        rids.add(rid)
        step = rec.get("step")
        if not isinstance(step, int) or step < 0:
            problems.append(f"{where}: bad step {step!r}")
        else:
            if step < last_step:
                problems.append(
                    f"{where}: step clock went backwards "
                    f"({last_step} -> {step})")
            last_step = max(last_step, step)
        if kind == "decision":
            if rec.get("stage") not in DECISION_STAGES:
                problems.append(
                    f"{where}: decision stage {rec.get('stage')!r} "
                    f"(want one of {DECISION_STAGES})")
            if not isinstance(rec.get("node"), int) or rec["node"] < 0:
                problems.append(f"{where}: bad node {rec.get('node')!r}")
            verdict = rec.get("verdict")
            if not isinstance(verdict, dict):
                problems.append(f"{where}: decision without verdict")
            elif verdict.get("status") not in VERDICT_STATUSES:
                problems.append(
                    f"{where}: verdict status {verdict.get('status')!r} "
                    f"(want one of {VERDICT_STATUSES})")
        elif kind == "disposition":
            d = rec.get("disposition")
            if d not in DISPOSITIONS:
                problems.append(
                    f"{where}: disposition {d!r} (want one of "
                    f"{DISPOSITIONS})")
            report = rec.get("report")
            if not isinstance(report, dict):
                problems.append(f"{where}: disposition without report")
            elif report.get("disposition") != d:
                problems.append(
                    f"{where}: report disposition "
                    f"{report.get('disposition')!r} != record {d!r}")
            disposition_count[rid] = disposition_count.get(rid, 0) + 1
        else:
            problems.append(f"{where}: unknown kind {kind!r}")
    # exactly one disposition per request appearing anywhere in the file
    # (preempted requests have their partial decisions dropped by the
    # trail, so any surviving decision implies the request finished)
    for rid in sorted(rids):
        n = disposition_count.get(rid, 0)
        if n != 1:
            problems.append(
                f"rid={rid} has {n} dispositions (want exactly 1)")
    return problems


def check_chrome(path: str) -> List[str]:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable chrome export ({e})"]
    evs = doc.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return [f"{path}: no traceEvents"]
    bad = [e for e in evs if "ph" not in e or "name" not in e
           or "pid" not in e]
    if bad:
        return [f"{path}: {len(bad)} chrome events missing "
                f"ph/name/pid"]
    return []


def check_file(path: str) -> List[str]:
    try:
        header, events = load(path)
    except (OSError, ValueError) as e:
        return [f"{path}: {e}"]
    if header.get("schema") == AUDIT_SCHEMA:
        problems = [f"{path}: {p}" for p in check_audit_records(events)]
        if not problems:
            n_disp = sum(1 for r in events
                         if r.get("kind") == "disposition")
            print(f"{path}: OK — {len(events)} audit records, "
                  f"{n_disp} dispositions")
        return problems
    problems = [f"{path}: {p}" for p in check_events(header, events)]
    base = path[: -len(".jsonl")] if path.endswith(".jsonl") else path
    chrome = base + ".chrome.json"
    if os.path.exists(chrome):
        problems += check_chrome(chrome)
    if not problems:
        n_req = sum(1 for ev in events
                    if ev.get("ph") == "B" and ev.get("name") == "request")
        final_step = max((e.get("step", 0) for e in events), default=0)
        print(f"{path}: OK — {len(events)} events, {n_req} requests, "
              f"final step {final_step}")
    return problems


def main(argv: List[str]) -> int:
    if not argv:
        print(__doc__.strip().splitlines()[0])
        print("usage: python tools/check_trace.py TRACE.jsonl [...]")
        return 2
    problems: List[str] = []
    for path in argv:
        problems += check_file(path)
    for p in problems:
        print(f"FAIL: {p}")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
