"""Microbenchmarks: Pallas kernels (interpret mode on CPU — structural
check + relative cost only; real perf numbers require a TPU) and the
pure-JAX reference paths that dominate the dry-run roofline."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit
from repro.kernels.dag_attention.ref import dag_attention_ref
from repro.core import ReasoningDAG, topology_from_dag


def _time(f, *args, n=3):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / n


def run():
    b, s, nh, nkv, hd = 1, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, nh, s, hd))
    k = jax.random.normal(ks[1], (b, nkv, s, hd))
    v = jax.random.normal(ks[2], (b, nkv, s, hd))
    dag = ReasoningDAG.from_deps({0: [], 1: [], 2: [0, 1]})
    topo, _ = topology_from_dag(dag, 64, {0: 64, 1: 64, 2: 32}, 32)
    topo = topo.pad_to(s)
    seg = jnp.asarray(topo.seg_id)[None]
    lay = jnp.asarray(topo.layer_id)[None]
    pos = jnp.asarray(topo.pos_id)[None]

    ref = jax.jit(lambda *a: dag_attention_ref(*a))
    dt = _time(ref, q, k, v, seg, lay, pos)
    flops = 4 * b * nh * s * s * hd
    emit("kernel_dag_attention_ref_jit", dt * 1e6,
         f"gflops_s={flops/dt/1e9:.1f};shape=b{b}s{s}h{nh}d{hd}")

    from repro.models.rglru import rglru_scan_ref
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (2, 512, 256)))
    bb = jax.random.normal(ks[1], (2, 512, 256))
    scan = jax.jit(lambda a, b: rglru_scan_ref(a, b))
    dt = _time(scan, a, bb)
    emit("kernel_rglru_assoc_scan_jit", dt * 1e6,
         f"elems_s={a.size/dt/1e6:.1f}M")
    return True


if __name__ == "__main__":
    run()
