"""Microbenchmarks for the Pallas kernel layer, CI-gated.

Three tiers per kernel workload on CPU:

* **dense** — the engine's reference path (``attention_backend="dense"``):
  per-token slot gather out of the flat pool + masked jnp SDPA.
* **paged XLA** — the paged-attention *schedule* executed as pure XLA
  (``paged_decode_attention_xla``): identical math and page-table
  contract as the Mosaic kernel, gathering whole pages instead of
  individual slots. On CPU its advantage is modest (~1.1-1.2x on the
  smoke shape) and confined to gather-bound regimes — many streams,
  small GQA KV rows, large pool — where the dense path pays per-token
  row-read overhead; at compute-bound shapes the tiers converge, and
  the schedule's large wins need the compiled Mosaic kernel on TPU.
  This dense/paged *ratio* is the row the CI regression gate tracks
  (same-machine, so runner speed cancels).
* **pallas interpret** — the actual kernel body through the Pallas
  interpreter: a *correctness emulation* with no performance meaning
  (orders of magnitude slower than anything compiled); timed on a tiny
  shape purely so CI notices if the kernel stops running at all. Real
  kernel perf numbers require a TPU (``interpret=False``).

Writes ``results/BENCH_kernel.json`` for ``benchmarks/check_regression.py``.
"""

from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):  # direct script execution
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    __package__ = "benchmarks"

from .common import emit
from repro.core import ReasoningDAG, topology_from_dag
from repro.engine.paged_model import decode_attention_dense
from repro.kernels.dag_attention.ref import dag_attention_ref
from repro.kernels.decode_attention.ops import (paged_decode_attention_flat,
                                                paged_decode_attention_xla)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _time(f, *args, n=3, trials=3):
    """Best-of-``trials`` mean over ``n`` synchronized calls. The
    warm-up call blocks so neither async compilation nor dispatch tail
    leaks into the timed loop, and the min-over-trials discards
    scheduler noise — both matter because these numbers feed the CI
    regression gate."""
    return _time_pair([(f, args)], n=n, trials=trials)[0]


def _time_pair(fs, n=3, trials=3):
    """Time several (f, args) thunks with *interleaved* trials (one
    trial of each per round, best-of-trials per thunk). Interleaving
    matters when the gated quantity is a ratio of two timings: timing
    tier A's trials back-to-back and then tier B's lets machine-state
    drift (frequency scaling, a co-tenant waking up) land entirely on
    one side and corrupt the ratio."""
    for f, args in fs:
        jax.block_until_ready(f(*args))  # compile + drain async dispatch
    best = [float("inf")] * len(fs)
    for _ in range(trials):
        for i, (f, args) in enumerate(fs):
            t0 = time.perf_counter()
            for _ in range(n):
                jax.block_until_ready(f(*args))
            best[i] = min(best[i], (time.perf_counter() - t0) / n)
    return best


# ------------------------------------------------- paged decode tiers ------
def _dense_gather_sdpa(q, k_slots, v_slots, pool_pos, chain_idx, chain_len,
                       q_pos):
    """The engine dense backend's per-layer decode attention — the
    *shipped* code (``paged_model.decode_attention_dense``), so the
    CI-gated dense-vs-paged ratio can't drift from the engine path."""
    b, nh, hd = q.shape
    out = decode_attention_dense(q[:, None], k_slots, v_slots, pool_pos,
                                 chain_idx, chain_len, q_pos)
    return out[:, 0].reshape(b, nh, hd)


def _paged_workload(b, nkv, g, hd, page_size, n_pages, live, seed=0):
    """One decode-step workload: b streams, each a scattered chain of
    ``live`` tokens (fork/join allocation order — pages are not
    contiguous in the pool)."""
    rng = np.random.default_rng(seed)
    nh = nkv * g
    n_slots = n_pages * page_size
    lp = live // page_size
    q = jax.random.normal(jax.random.PRNGKey(seed), (b, nh, hd))
    ks = jax.random.normal(jax.random.PRNGKey(seed + 1), (n_slots, nkv, hd))
    vs = jax.random.normal(jax.random.PRNGKey(seed + 2), (n_slots, nkv, hd))
    pos = jnp.asarray(np.arange(n_slots) % live, jnp.int32)
    pt = np.stack([rng.permutation(n_pages)[:lp] for _ in range(b)])
    pt = pt.astype(np.int32)
    # the token chain is the page table expanded slot-wise (full pages)
    chain = (pt[:, :, None] * page_size
             + np.arange(page_size)[None, None, :]).reshape(b, live)
    chain = chain.astype(np.int32)
    return dict(
        q=q, ks=ks, vs=vs, pos=pos,
        chain=jnp.asarray(chain),
        clen=jnp.full((b,), live, jnp.int32),
        qpos=jnp.full((b,), live, jnp.int32),
        pt=jnp.asarray(pt),
        pv=jnp.full((b, lp), page_size, jnp.int32),
        page_size=page_size, n_pages=n_pages,
    )


def bench_paged_decode(b=64, nkv=2, g=2, hd=64, page_size=16, n_pages=8192,
                       live=64, n=10, trials=5):
    """Dense per-slot gather vs the paged schedule, one decode step.

    The default shape is the serving-relevant regime where the paged
    schedule's CPU advantage lives: many concurrent streams with small
    GQA KV rows over a large pool, so the dense path's per-token gather
    overhead (b*live tiny row reads) dominates, while the paged path
    reads whole pages. At compute-bound shapes the two tiers converge —
    the page-table schedule's large wins need the Mosaic kernel on TPU.
    """
    w = _paged_workload(b, nkv, g, hd, page_size, n_pages, live)
    dense = jax.jit(_dense_gather_sdpa)
    kp = w["ks"].reshape(n_pages, page_size, nkv, hd)
    vp = w["vs"].reshape(n_pages, page_size, nkv, hd)
    pp = w["pos"].reshape(n_pages, page_size)
    xla = lambda *a: paged_decode_attention_xla(*a)
    dt_dense, dt_xla = _time_pair(
        [(dense, (w["q"], w["ks"], w["vs"], w["pos"], w["chain"],
                  w["clen"], w["qpos"])),
         (xla, (w["q"], kp, vp, pp, w["pt"], w["pv"], w["qpos"]))],
        n=n, trials=trials)
    # numeric agreement between the two paths (same math, different
    # schedule): the backend-parity contract at the kernel level
    o_dense = dense(w["q"], w["ks"], w["vs"], w["pos"], w["chain"],
                    w["clen"], w["qpos"])
    o_xla = xla(w["q"], kp, vp, pp, w["pt"], w["pv"], w["qpos"])
    max_err = float(jnp.max(jnp.abs(o_dense - o_xla)))
    speedup = dt_dense / dt_xla
    shape = f"b{b}kv{nkv}g{g}d{hd}ps{page_size}live{live}"
    emit("kernel_paged_decode_dense_sdpa", dt_dense * 1e6, f"shape={shape}")
    emit("kernel_paged_decode_paged_xla", dt_xla * 1e6,
         f"speedup_vs_dense={speedup:.2f}x;max_abs_err={max_err:.2e}")
    return {
        "shape": shape, "dense_us": dt_dense * 1e6, "paged_xla_us": dt_xla * 1e6,
        "speedup_xla_vs_dense": speedup, "max_abs_err": max_err,
    }


def bench_pallas_interpret(b=2, nkv=2, g=2, hd=64, page_size=8, n_pages=32,
                           live=32, n=2):
    """Tiny-shape liveness probe of the real kernel via the interpreter
    (structural only — interpret timing is meaningless as perf)."""
    w = _paged_workload(b, nkv, g, hd, page_size, n_pages, live, seed=3)
    f = lambda *a: paged_decode_attention_flat(
        *a, page_size=page_size, interpret=True)
    dt = _time(f, w["q"], w["ks"], w["vs"], w["pos"], w["pt"], w["pv"],
               w["qpos"], n=n)
    o_kernel = f(w["q"], w["ks"], w["vs"], w["pos"], w["pt"], w["pv"],
                 w["qpos"])
    kp = w["ks"].reshape(n_pages, page_size, nkv, hd)
    vp = w["vs"].reshape(n_pages, page_size, nkv, hd)
    pp = w["pos"].reshape(n_pages, page_size)
    o_xla = paged_decode_attention_xla(w["q"], kp, vp, pp, w["pt"], w["pv"],
                                       w["qpos"])
    max_err = float(jnp.max(jnp.abs(o_kernel - o_xla)))
    emit("kernel_paged_decode_pallas_interpret", dt * 1e6,
         f"structural_only=1;max_abs_err_vs_xla={max_err:.2e}")
    return {"interpret_us": dt * 1e6, "max_abs_err_vs_xla": max_err}


# ----------------------------------------------------- dag attention -------
def bench_dag_attention(b=1, s=256, nh=4, nkv=2, hd=64, n=3):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, nh, s, hd))
    k = jax.random.normal(ks[1], (b, nkv, s, hd))
    v = jax.random.normal(ks[2], (b, nkv, s, hd))
    dag = ReasoningDAG.from_deps({0: [], 1: [], 2: [0, 1]})
    topo, _ = topology_from_dag(dag, 64, {0: 64, 1: 64, 2: 32}, 32)
    topo = topo.pad_to(s)
    seg = jnp.asarray(topo.seg_id)[None]
    lay = jnp.asarray(topo.layer_id)[None]
    pos = jnp.asarray(topo.pos_id)[None]
    ref = jax.jit(lambda *a: dag_attention_ref(*a))
    dt = _time(ref, q, k, v, seg, lay, pos, n=n)
    flops = 4 * b * nh * s * s * hd
    emit("kernel_dag_attention_ref_jit", dt * 1e6,
         f"gflops_s={flops/dt/1e9:.1f};shape=b{b}s{s}h{nh}d{hd}")
    return {"ref_jit_us": dt * 1e6}


def bench_rglru(n=3):
    from repro.models.rglru import rglru_scan_ref
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (2, 512, 256)))
    bb = jax.random.normal(ks[1], (2, 512, 256))
    scan = jax.jit(lambda a, b: rglru_scan_ref(a, b))
    dt = _time(scan, a, bb, n=n)
    emit("kernel_rglru_assoc_scan_jit", dt * 1e6,
         f"elems_s={a.size/dt/1e6:.1f}M")
    return {"jit_us": dt * 1e6}


def run(smoke: bool = False):
    out = {"config": {"smoke": smoke}}
    out["paged_decode"] = bench_paged_decode()   # the CI-gated shape
    out["pallas_interpret"] = bench_pallas_interpret()
    if not smoke:
        out["paged_decode_long"] = bench_paged_decode(
            b=8, nkv=2, g=2, hd=64, page_size=64, n_pages=4096, live=2048)
        out["dag_attention"] = bench_dag_attention()
        out["rglru"] = bench_rglru()
    if not out["paged_decode"]["max_abs_err"] < 1e-4:
        raise ValueError(f"dense/paged parity broken: {out['paged_decode']}")
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_kernel.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {os.path.relpath(path)}")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    run(smoke=args.smoke)
