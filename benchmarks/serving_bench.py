"""Serving benchmark: continuous batching under live traffic.

Poisson arrivals over a mixed workload of DAG shapes (wide fan-out,
deep chains, diamonds, serial requests) and varied prompt lengths,
driven through the :class:`ContinuousScheduler` once per admission
policy (FCFS, chain-aware) plus the closed-batch baseline (admit only
into an idle engine — the historical ``generate()`` loop). Emits one
CSV line per run and writes the full SLA reports (throughput, TTFT,
TPOT, e2e, goodput, preemptions) to ``results/BENCH_serving.json``.

A final *traced* fcfs pass re-runs the same workload with
``EngineConfig.trace`` on: it asserts the step count is unchanged
(tracing is passive), dumps ``results/serving_trace.jsonl`` plus its
Perfetto-loadable Chrome twin, and records deterministic event counts
that ``check_regression.py`` gates against the committed baseline.

A *verified-serving* pass then drives stage-typed plans (critic and
guardrail steps) through the same scheduler twice — audit trail off,
then on with tracing — asserting auditing is passive (identical step
count), dumping ``results/serving_verified_trace.jsonl`` and
``results/serving_audit.jsonl``, and recording the deterministic
verdict/disposition tallies plus ``verified_per_step`` and the
critic-priority event count for the regression gate.
"""

from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

import numpy as np

if __package__ in (None, ""):  # direct script execution
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    __package__ = "benchmarks"

from .common import default_engine_cfg, emit, eval_prompts, get_artifacts
from repro.core.plan import OutlineStep, ReasoningPlan
from repro.data import Tokenizer
from repro.engine import MedVerseEngine
from repro.serving import ContinuousScheduler, ServeRequest

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _plan(shape: str) -> str:
    """Plan text for one of the mixed DAG shapes."""
    if shape == "wide":
        steps = [OutlineStep(index=i + 1, label=f"assess factor {i + 1}",
                             dependencies=()) for i in range(4)]
    elif shape == "deep":
        steps = [OutlineStep(index=i + 1, label=f"stage {i + 1}",
                             dependencies=(i,) if i else ())
                 for i in range(3)]
    elif shape == "diamond":
        steps = [OutlineStep(index=1, label="history", dependencies=()),
                 OutlineStep(index=2, label="labs", dependencies=()),
                 OutlineStep(index=3, label="synthesize",
                             dependencies=(1, 2))]
    else:  # serial
        steps = [OutlineStep(index=1, label="reason", dependencies=())]
    return ReasoningPlan(steps=tuple(steps)).serialize()


SHAPES = ("wide", "deep", "diamond", "serial")

# stage-typed shapes for the verified-serving pass. "gate" is the
# critic-priority shape: the critic's verdict unblocks two sibling
# branches at once (unblock count 2), so the engine's stage-aware
# spawn prioritization fires deterministically on every request.
STAGED_SHAPES = ("gate", "checked-diamond")

# words the staged plans add over the artifact corpus; the trained
# bench model reserves 64 embedding rows of slack above the corpus
# vocabulary exactly so workload extensions like this stay in-bounds
_STAGE_WORDS = ("Stage:", "critic", "guardrail", "verify", "findings",
                "screen", "safety", "treatment", "assess", "history",
                "synthesize", "5:")


def _staged_plan(shape: str) -> str:
    if shape == "gate":
        steps = [
            OutlineStep(index=1, label="assess history", dependencies=()),
            OutlineStep(index=2, label="verify findings",
                        dependencies=(1,), stage="critic"),
            OutlineStep(index=3, label="synthesize diagnosis",
                        dependencies=(2,)),
            OutlineStep(index=4, label="assess treatment",
                        dependencies=(2,)),
            OutlineStep(index=5, label="screen safety",
                        dependencies=(3, 4), stage="guardrail"),
        ]
    else:  # checked-diamond
        steps = [
            OutlineStep(index=1, label="history", dependencies=()),
            OutlineStep(index=2, label="labs", dependencies=()),
            OutlineStep(index=3, label="verify findings",
                        dependencies=(1, 2), stage="critic"),
            OutlineStep(index=4, label="synthesize",
                        dependencies=(3,)),
        ]
    return ReasoningPlan(steps=tuple(steps)).serialize()


def _verified_tok(base: Tokenizer) -> Tokenizer:
    """Extend a copy of the artifact tokenizer with the stage grammar
    words (appended ids only — every existing id is unchanged, so the
    trained embeddings still line up)."""
    vocab = dict(base.vocab)
    for w in _STAGE_WORDS:
        vocab.setdefault(w, len(vocab))
    return Tokenizer(vocab)


def make_workload(prompts, n_requests: int, rate: float,
                  seed: int = 0, deadline_s=None):
    """Poisson arrival process (seeded exponential inter-arrival gaps at
    ``rate`` requests per scheduler-clock unit — seconds under the wall
    clock, decode steps under the step clock) over round-robin DAG
    shapes and cycled, varied-length prompts."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    arrivals = np.cumsum(gaps)
    workload = []
    for i in range(n_requests):
        shape = SHAPES[i % len(SHAPES)]
        prompt = prompts[i % len(prompts)]
        workload.append(ServeRequest(
            prompt=prompt, plan=_plan(shape), arrival=float(arrivals[i]),
            deadline_s=deadline_s))
    return workload


def _serve(art, workload, policy: str, closed_batch: bool, ecfg,
           clock: str = "wall", tok: Tokenizer = None):
    eng = MedVerseEngine(art.params_mask, art.cfg,
                         tok or art.corpus.tokenizer, ecfg)
    eng.warmup()   # pre-compile decode buckets: keep XLA out of the SLAs
    sched = ContinuousScheduler(eng, policy=policy, clock=clock,
                                closed_batch=closed_batch, deadline_s=30.0)
    # fresh copies per run: ServeRequest carries per-run mutable state
    reqs = [ServeRequest(prompt=r.prompt, plan=r.plan, arrival=r.arrival,
                         deadline_s=r.deadline_s) for r in workload]
    return sched.run(reqs), eng


def _traced_pass(art, workload, ecfg, clock: str, fcfs_report: dict):
    """Re-run the fcfs workload with tracing on: assert tracing is
    passive (identical step count), dump the Perfetto-loadable trace to
    ``results/``, and return the deterministic event-count section the
    regression gate diffs (event counts on the step clock are exactly
    reproducible for a given commit — wall timestamps inside the trace
    are recorded but never gated)."""
    from repro.obs import request_timelines, validate_spans

    trace_path = os.path.join(RESULTS, "serving_trace.jsonl")
    ecfg_t = dataclasses.replace(ecfg, trace=trace_path)
    rep, eng = _serve(art, workload, "fcfs", False, ecfg_t, clock)
    assert rep.n_steps == fcfs_report["n_steps"], (
        f"tracing changed the schedule: {rep.n_steps} steps traced vs "
        f"{fcfs_report['n_steps']} untraced")
    os.makedirs(RESULTS, exist_ok=True)
    jsonl_path, chrome_path = eng.dump_trace()
    problems = validate_spans(eng.obs.events)
    counts: dict = {}
    for ev in eng.obs.events:
        key = f"{ev['ph']}:{ev['name']}"
        counts[key] = counts.get(key, 0) + 1
    timelines = request_timelines(eng.obs.events)
    max_overlap = max(
        (tl.max_overlap for tl in timelines.values()), default=0)
    # analytic cost section: exact machine-independent integers the
    # regression gate pins bit-for-bit (not banded)
    cost = dict(eng.cost.summary(),
                padding_waste_ratio=round(
                    eng.cost.padding_waste_ratio(), 6),
                compiles=eng.compiles.compiles_total,
                recompiles_after_warmup=(
                    eng.compiles.recompiles_after_warmup))
    assert cost["recompiles_after_warmup"] == 0, (
        f"bucket-ladder invariant broken: "
        f"{eng.compiles.keys[-cost['recompiles_after_warmup']:]} "
        f"compiled after warmup")
    print(f"# traced fcfs pass: {len(eng.obs.events)} events, "
          f"{len(problems)} span problems, max_overlap={max_overlap}, "
          f"padding_waste={cost['padding_waste_ratio']:.1%}, "
          f"recompiles_after_warmup={cost['recompiles_after_warmup']} "
          f"-> {os.path.relpath(jsonl_path)}, "
          f"{os.path.relpath(chrome_path)}")
    return {
        "n_events": len(eng.obs.events),
        "event_counts": dict(sorted(counts.items())),
        "span_problems": len(problems),
        "max_overlap": max_overlap,
        "n_steps": rep.n_steps,
        "cost": cost,
        "jsonl": os.path.relpath(jsonl_path),
        "chrome": os.path.relpath(chrome_path),
    }


def _verified_pass(art, prompts, n_requests: int, rate: float, ecfg,
                   clock: str):
    """Verified-serving workload: stage-typed plans through the
    scheduler, audit off then audit+trace on. Asserts auditing is
    passive (identical step count), dumps the audit JSONL + trace
    artifacts, and returns the deterministic verdict/disposition
    section the regression gate pins."""
    from repro.obs import validate_spans

    tok = _verified_tok(art.corpus.tokenizer)
    assert tok.vocab_size <= art.cfg.vocab_size, (
        f"staged vocab {tok.vocab_size} exceeds the trained model's "
        f"{art.cfg.vocab_size} embedding rows")
    rng = np.random.default_rng(1)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    workload = [
        ServeRequest(prompt=prompts[i % len(prompts)],
                     plan=_staged_plan(
                         STAGED_SHAPES[i % len(STAGED_SHAPES)]),
                     arrival=float(arrivals[i]), deadline_s=30.0)
        for i in range(n_requests)]
    # longer step budget than the latency passes: critic bodies need a
    # few content words for the rule extractor to decide (a 4-token
    # stub abstains on every decision)
    ecfg_off = dataclasses.replace(ecfg, max_step_tokens=12)
    rep_off, _ = _serve(art, workload, "fcfs", False, ecfg_off, clock,
                        tok=tok)
    os.makedirs(RESULTS, exist_ok=True)
    audit_path = os.path.join(RESULTS, "serving_audit.jsonl")
    trace_path = os.path.join(RESULTS, "serving_verified_trace.jsonl")
    ecfg_on = dataclasses.replace(ecfg_off, audit=audit_path,
                                  trace=trace_path)
    rep, eng = _serve(art, workload, "fcfs", False, ecfg_on, clock,
                      tok=tok)
    assert rep.n_steps == rep_off.n_steps, (
        f"auditing changed the schedule: {rep.n_steps} steps audited "
        f"vs {rep_off.n_steps} unaudited")
    jsonl_path, chrome_path = eng.dump_trace()
    audit_path = eng.dump_audit()
    problems = validate_spans(eng.obs.events)
    counts = eng.audit.counts()
    critic_priority = sum(1 for ev in eng.obs.events
                          if ev["name"] == "critic_priority")
    emit("serving_verified",
         rep.duration_s / max(rep.total_tokens, 1) * 1e6,
         f"verified={rep.n_verified}/{rep.n_requests};"
         f"vps={rep.verified_per_step:.5f};"
         f"pass={counts['verdict_pass']};fail={counts['verdict_fail']};"
         f"abstain={counts['verdict_abstain']};"
         f"critic_priority={critic_priority}")
    print(f"# verified pass: {rep.summary()}")
    print(f"# audit: {counts['records']} records "
          f"({counts['decisions']} decisions), "
          f"{len(problems)} span problems, "
          f"critic_priority_events={critic_priority} "
          f"-> {os.path.relpath(audit_path)}, "
          f"{os.path.relpath(jsonl_path)}")
    return {
        "n_steps": rep.n_steps,
        "n_requests": rep.n_requests,
        "n_audit_records": counts["records"],
        "verdicts": {s: counts[f"verdict_{s}"]
                     for s in ("pass", "fail", "abstain")},
        "dispositions": {d: counts[d]
                         for d in ("verified", "refuted", "unverified")},
        "n_verified": rep.n_verified,
        "verified_per_step": round(rep.verified_per_step, 6),
        "critic_priority_events": critic_priority,
        "span_problems": len(problems),
        "stage_ttft_steps": rep.stage_ttft_steps,
        "stage_tpot_steps": rep.stage_tpot_steps,
        "audit_jsonl": os.path.relpath(audit_path),
        "jsonl": os.path.relpath(jsonl_path),
        "chrome": os.path.relpath(chrome_path),
    }


def run(art=None, n_requests: int = 16, rate: float = 4.0,
        smoke: bool = False):
    clock = "wall"
    if smoke:
        # CI configuration: the step clock makes the gated step metrics
        # (n_steps, ttft_steps) exactly reproducible across machines —
        # seeded Poisson arrivals in decode steps, no wall time anywhere
        # in the schedule. 0.5 req/step staggers 6 arrivals over ~12
        # steps, the same early-arrival profile the wall config gives on
        # a typical CPU. Wall-clock metrics are still reported but have
        # no cross-machine meaning here (and are not gated).
        n_requests, rate, clock = 6, 0.5, "step"
    art = art or get_artifacts()
    prompts = [p for p, _, _, _ in eval_prompts(art.corpus, n=8)]
    ecfg = default_engine_cfg(
        max_slots=8, n_pages=4096,
        max_step_tokens=4 if smoke else 12,
        max_conclusion_tokens=4 if smoke else 16)
    workload = make_workload(prompts, n_requests, rate)
    runs = [("fcfs", False), ("chain-aware", False), ("fcfs", True)]
    reports = {}
    for policy, closed in runs:
        tag = f"{policy}{'-closed' if closed else ''}"
        t0 = time.time()
        rep, _ = _serve(art, workload, policy, closed, ecfg, clock)
        reports[tag] = rep.to_dict()
        emit(f"serving_{tag}",
             rep.duration_s / max(rep.total_tokens, 1) * 1e6,
             f"tput={rep.throughput_tok_s:.1f}tok_s;"
             f"ttft_ms={rep.ttft_s['mean']*1e3:.0f};"
             f"ttft_steps={rep.ttft_steps['mean']:.1f};"
             f"tpot_ms={rep.tpot_s['mean']*1e3:.1f};"
             f"goodput={rep.goodput:.2f};"
             f"preempt={rep.n_preemptions}")
        print(f"# {rep.summary()} ({time.time()-t0:.1f}s)")
        assert rep.n_completed == n_requests, (
            f"{tag}: {rep.n_completed}/{n_requests} completed")
    # continuous batching must not lose to the closed-batch baseline on
    # time-to-first-token (compared in decode steps — deterministic and
    # immune to first-run compilation noise in wall time)
    if reports["fcfs"]["ttft_steps"]["mean"] > reports["fcfs-closed"][
            "ttft_steps"]["mean"]:
        print("# WARNING: continuous TTFT did not beat closed batch")
    # one traced fcfs pass: proves tracing is passive (identical step
    # count) and produces the deterministic event-count section the
    # regression gate diffs, plus the Perfetto-loadable trace artifact
    trace_section = _traced_pass(art, workload, ecfg, clock,
                                 reports["fcfs"])
    # verified-serving pass: stage-typed plans, audit trail on
    verified_section = _verified_pass(art, prompts, n_requests, rate,
                                      ecfg, clock)
    os.makedirs(RESULTS, exist_ok=True)
    out = {"config": {"n_requests": n_requests, "rate": rate,
                      "clock": clock, "max_slots": ecfg.max_slots,
                      "attention_backend": ecfg.attention_backend,
                      "shapes": SHAPES,
                      "staged_shapes": STAGED_SHAPES},
           "runs": reports,
           "trace": trace_section,
           "verified": verified_section}
    path = os.path.join(RESULTS, "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {os.path.relpath(path)}")
    return reports


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=4.0)
    args = ap.parse_args()
    run(n_requests=args.requests, rate=args.rate, smoke=args.smoke)
