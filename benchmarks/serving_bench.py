"""Serving benchmark: continuous batching under live traffic.

Poisson arrivals over a mixed workload of DAG shapes (wide fan-out,
deep chains, diamonds, serial requests) and varied prompt lengths,
driven through the :class:`ContinuousScheduler` once per admission
policy (FCFS, chain-aware) plus the closed-batch baseline (admit only
into an idle engine — the historical ``generate()`` loop). Emits one
CSV line per run and writes the full SLA reports (throughput, TTFT,
TPOT, e2e, goodput, preemptions) to ``results/BENCH_serving.json``.

A final *traced* fcfs pass re-runs the same workload with
``EngineConfig.trace`` on: it asserts the step count is unchanged
(tracing is passive), dumps ``results/serving_trace.jsonl`` plus its
Perfetto-loadable Chrome twin, and records deterministic event counts
that ``check_regression.py`` gates against the committed baseline.

A *verified-serving* pass then drives stage-typed plans (critic and
guardrail steps) through the same scheduler twice — audit trail off,
then on with tracing — asserting auditing is passive (identical step
count), dumping ``results/serving_verified_trace.jsonl`` and
``results/serving_audit.jsonl``, and recording the deterministic
verdict/disposition tallies plus ``verified_per_step`` and the
critic-priority event count for the regression gate.

A *quantization* pass runs the fcfs workload once per KV dtype with
the dtype pinned (independent of ``$ENGINE_KV_DTYPE``): temp-0 step
counts must be identical and the analytic KV byte totals must sit at
exactly 0.25x (int8 stores 1 byte per f32's 4 — both gated exactly).
A pressure sub-run then gives both dtypes the *same byte budget*
(``EngineConfig.kv_pool_bytes``) sized to force f32 out-of-pages
preemptions; int8 buys ~4x the pages from those bytes and must
preempt strictly less.

A *chunked-prefill* pass mixes long prompts into a burst of short
ones and compares ``prefill_chunk=0`` (monolithic prefill at
admission) against chunked ingestion on the compute-clock TTFT tail
(``ttft_flops`` — engine attention FLOPs between arrival and first
token, deterministic and sensitive to head-of-line prompt stalls the
step clock cannot see). Chunked must strictly improve the p95.
"""

from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

import numpy as np

if __package__ in (None, ""):  # direct script execution
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    __package__ = "benchmarks"

from .common import default_engine_cfg, emit, eval_prompts, get_artifacts
from repro.core.plan import OutlineStep, ReasoningPlan
from repro.data import Tokenizer
from repro.engine import MedVerseEngine
from repro.serving import ContinuousScheduler, ServeRequest

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _plan(shape: str) -> str:
    """Plan text for one of the mixed DAG shapes."""
    if shape == "wide":
        steps = [OutlineStep(index=i + 1, label=f"assess factor {i + 1}",
                             dependencies=()) for i in range(4)]
    elif shape == "deep":
        steps = [OutlineStep(index=i + 1, label=f"stage {i + 1}",
                             dependencies=(i,) if i else ())
                 for i in range(3)]
    elif shape == "diamond":
        steps = [OutlineStep(index=1, label="history", dependencies=()),
                 OutlineStep(index=2, label="labs", dependencies=()),
                 OutlineStep(index=3, label="synthesize",
                             dependencies=(1, 2))]
    else:  # serial
        steps = [OutlineStep(index=1, label="reason", dependencies=())]
    return ReasoningPlan(steps=tuple(steps)).serialize()


SHAPES = ("wide", "deep", "diamond", "serial")

# stage-typed shapes for the verified-serving pass. "gate" is the
# critic-priority shape: the critic's verdict unblocks two sibling
# branches at once (unblock count 2), so the engine's stage-aware
# spawn prioritization fires deterministically on every request.
STAGED_SHAPES = ("gate", "checked-diamond")

# words the staged plans add over the artifact corpus; the trained
# bench model reserves 64 embedding rows of slack above the corpus
# vocabulary exactly so workload extensions like this stay in-bounds
_STAGE_WORDS = ("Stage:", "critic", "guardrail", "verify", "findings",
                "screen", "safety", "treatment", "assess", "history",
                "synthesize", "5:")


def _staged_plan(shape: str) -> str:
    if shape == "gate":
        steps = [
            OutlineStep(index=1, label="assess history", dependencies=()),
            OutlineStep(index=2, label="verify findings",
                        dependencies=(1,), stage="critic"),
            OutlineStep(index=3, label="synthesize diagnosis",
                        dependencies=(2,)),
            OutlineStep(index=4, label="assess treatment",
                        dependencies=(2,)),
            OutlineStep(index=5, label="screen safety",
                        dependencies=(3, 4), stage="guardrail"),
        ]
    else:  # checked-diamond
        steps = [
            OutlineStep(index=1, label="history", dependencies=()),
            OutlineStep(index=2, label="labs", dependencies=()),
            OutlineStep(index=3, label="verify findings",
                        dependencies=(1, 2), stage="critic"),
            OutlineStep(index=4, label="synthesize",
                        dependencies=(3,)),
        ]
    return ReasoningPlan(steps=tuple(steps)).serialize()


def _verified_tok(base: Tokenizer) -> Tokenizer:
    """Extend a copy of the artifact tokenizer with the stage grammar
    words (appended ids only — every existing id is unchanged, so the
    trained embeddings still line up)."""
    vocab = dict(base.vocab)
    for w in _STAGE_WORDS:
        vocab.setdefault(w, len(vocab))
    return Tokenizer(vocab)


def make_workload(prompts, n_requests: int, rate: float,
                  seed: int = 0, deadline_s=None):
    """Poisson arrival process (seeded exponential inter-arrival gaps at
    ``rate`` requests per scheduler-clock unit — seconds under the wall
    clock, decode steps under the step clock) over round-robin DAG
    shapes and cycled, varied-length prompts."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    arrivals = np.cumsum(gaps)
    workload = []
    for i in range(n_requests):
        shape = SHAPES[i % len(SHAPES)]
        prompt = prompts[i % len(prompts)]
        workload.append(ServeRequest(
            prompt=prompt, plan=_plan(shape), arrival=float(arrivals[i]),
            deadline_s=deadline_s))
    return workload


def _serve(art, workload, policy: str, closed_batch: bool, ecfg,
           clock: str = "wall", tok: Tokenizer = None):
    eng = MedVerseEngine(art.params_mask, art.cfg,
                         tok or art.corpus.tokenizer, ecfg)
    eng.warmup()   # pre-compile decode buckets: keep XLA out of the SLAs
    sched = ContinuousScheduler(eng, policy=policy, clock=clock,
                                closed_batch=closed_batch, deadline_s=30.0)
    # fresh copies per run: ServeRequest carries per-run mutable state
    reqs = [ServeRequest(prompt=r.prompt, plan=r.plan, arrival=r.arrival,
                         deadline_s=r.deadline_s) for r in workload]
    return sched.run(reqs), eng


def _traced_pass(art, workload, ecfg, clock: str, fcfs_report: dict):
    """Re-run the fcfs workload with tracing on: assert tracing is
    passive (identical step count), dump the Perfetto-loadable trace to
    ``results/``, and return the deterministic event-count section the
    regression gate diffs (event counts on the step clock are exactly
    reproducible for a given commit — wall timestamps inside the trace
    are recorded but never gated)."""
    from repro.obs import request_timelines, validate_spans

    trace_path = os.path.join(RESULTS, "serving_trace.jsonl")
    ecfg_t = dataclasses.replace(ecfg, trace=trace_path)
    rep, eng = _serve(art, workload, "fcfs", False, ecfg_t, clock)
    assert rep.n_steps == fcfs_report["n_steps"], (
        f"tracing changed the schedule: {rep.n_steps} steps traced vs "
        f"{fcfs_report['n_steps']} untraced")
    os.makedirs(RESULTS, exist_ok=True)
    jsonl_path, chrome_path = eng.dump_trace()
    problems = validate_spans(eng.obs.events)
    counts: dict = {}
    for ev in eng.obs.events:
        key = f"{ev['ph']}:{ev['name']}"
        counts[key] = counts.get(key, 0) + 1
    timelines = request_timelines(eng.obs.events)
    max_overlap = max(
        (tl.max_overlap for tl in timelines.values()), default=0)
    # analytic cost section: exact machine-independent integers the
    # regression gate pins bit-for-bit (not banded)
    cost = dict(eng.cost.summary(),
                padding_waste_ratio=round(
                    eng.cost.padding_waste_ratio(), 6),
                compiles=eng.compiles.compiles_total,
                recompiles_after_warmup=(
                    eng.compiles.recompiles_after_warmup))
    assert cost["recompiles_after_warmup"] == 0, (
        f"bucket-ladder invariant broken: "
        f"{eng.compiles.keys[-cost['recompiles_after_warmup']:]} "
        f"compiled after warmup")
    print(f"# traced fcfs pass: {len(eng.obs.events)} events, "
          f"{len(problems)} span problems, max_overlap={max_overlap}, "
          f"padding_waste={cost['padding_waste_ratio']:.1%}, "
          f"recompiles_after_warmup={cost['recompiles_after_warmup']} "
          f"-> {os.path.relpath(jsonl_path)}, "
          f"{os.path.relpath(chrome_path)}")
    return {
        "n_events": len(eng.obs.events),
        "event_counts": dict(sorted(counts.items())),
        "span_problems": len(problems),
        "max_overlap": max_overlap,
        "n_steps": rep.n_steps,
        "cost": cost,
        "jsonl": os.path.relpath(jsonl_path),
        "chrome": os.path.relpath(chrome_path),
    }


def _verified_pass(art, prompts, n_requests: int, rate: float, ecfg,
                   clock: str):
    """Verified-serving workload: stage-typed plans through the
    scheduler, audit off then audit+trace on. Asserts auditing is
    passive (identical step count), dumps the audit JSONL + trace
    artifacts, and returns the deterministic verdict/disposition
    section the regression gate pins."""
    from repro.obs import validate_spans

    tok = _verified_tok(art.corpus.tokenizer)
    assert tok.vocab_size <= art.cfg.vocab_size, (
        f"staged vocab {tok.vocab_size} exceeds the trained model's "
        f"{art.cfg.vocab_size} embedding rows")
    rng = np.random.default_rng(1)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    workload = [
        ServeRequest(prompt=prompts[i % len(prompts)],
                     plan=_staged_plan(
                         STAGED_SHAPES[i % len(STAGED_SHAPES)]),
                     arrival=float(arrivals[i]), deadline_s=30.0)
        for i in range(n_requests)]
    # longer step budget than the latency passes: critic bodies need a
    # few content words for the rule extractor to decide (a 4-token
    # stub abstains on every decision)
    ecfg_off = dataclasses.replace(ecfg, max_step_tokens=12)
    rep_off, _ = _serve(art, workload, "fcfs", False, ecfg_off, clock,
                        tok=tok)
    os.makedirs(RESULTS, exist_ok=True)
    audit_path = os.path.join(RESULTS, "serving_audit.jsonl")
    trace_path = os.path.join(RESULTS, "serving_verified_trace.jsonl")
    ecfg_on = dataclasses.replace(ecfg_off, audit=audit_path,
                                  trace=trace_path)
    rep, eng = _serve(art, workload, "fcfs", False, ecfg_on, clock,
                      tok=tok)
    assert rep.n_steps == rep_off.n_steps, (
        f"auditing changed the schedule: {rep.n_steps} steps audited "
        f"vs {rep_off.n_steps} unaudited")
    jsonl_path, chrome_path = eng.dump_trace()
    audit_path = eng.dump_audit()
    problems = validate_spans(eng.obs.events)
    counts = eng.audit.counts()
    critic_priority = sum(1 for ev in eng.obs.events
                          if ev["name"] == "critic_priority")
    emit("serving_verified",
         rep.duration_s / max(rep.total_tokens, 1) * 1e6,
         f"verified={rep.n_verified}/{rep.n_requests};"
         f"vps={rep.verified_per_step:.5f};"
         f"pass={counts['verdict_pass']};fail={counts['verdict_fail']};"
         f"abstain={counts['verdict_abstain']};"
         f"critic_priority={critic_priority}")
    print(f"# verified pass: {rep.summary()}")
    print(f"# audit: {counts['records']} records "
          f"({counts['decisions']} decisions), "
          f"{len(problems)} span problems, "
          f"critic_priority_events={critic_priority} "
          f"-> {os.path.relpath(audit_path)}, "
          f"{os.path.relpath(jsonl_path)}")
    return {
        "n_steps": rep.n_steps,
        "n_requests": rep.n_requests,
        "n_audit_records": counts["records"],
        "verdicts": {s: counts[f"verdict_{s}"]
                     for s in ("pass", "fail", "abstain")},
        "dispositions": {d: counts[d]
                         for d in ("verified", "refuted", "unverified")},
        "n_verified": rep.n_verified,
        "verified_per_step": round(rep.verified_per_step, 6),
        "critic_priority_events": critic_priority,
        "span_problems": len(problems),
        "stage_ttft_steps": rep.stage_ttft_steps,
        "stage_tpot_steps": rep.stage_tpot_steps,
        "audit_jsonl": os.path.relpath(audit_path),
        "jsonl": os.path.relpath(jsonl_path),
        "chrome": os.path.relpath(chrome_path),
    }


def _quantization_pass(art, workload, ecfg, clock: str,
                       pressure_pages: int):
    """int8-vs-f32 KV pages on the same workload, dtypes pinned so the
    section is identical on every CI matrix leg regardless of
    ``$ENGINE_KV_DTYPE``.

    Two claims, both asserted in-process and pinned exactly by
    ``check_regression.py``:

    * **parity + bytes** — temp-0 schedules are identical (same step
      count) and the analytic KV byte totals are exactly 0.25x under
      int8 (1-byte cells vs 4-byte f32; the per-page scale rows are
      pool *capacity* overhead, deliberately excluded from per-token
      traffic accounting).
    * **capacity** — at the *same byte budget*
      (``pressure_pages`` f32 pages' worth, via
      ``EngineConfig.kv_pool_bytes``) int8 buys ~4x the pages and
      preempts strictly less on the pressure workload.
    """
    from repro.engine.kvcache import PoolConfig, pages_for_budget

    ecfg_f = dataclasses.replace(ecfg, kv_dtype="f32")
    ecfg_q = dataclasses.replace(ecfg, kv_dtype="int8")
    rep_f, eng_f = _serve(art, workload, "fcfs", False, ecfg_f, clock)
    rep_q, eng_q = _serve(art, workload, "fcfs", False, ecfg_q, clock)
    assert rep_q.n_steps == rep_f.n_steps, (
        f"int8 KV changed the temp-0 schedule: {rep_q.n_steps} steps "
        f"vs {rep_f.n_steps} under f32")
    wf = eng_f.cost.total("kv_write_bytes")
    wq = eng_q.cost.total("kv_write_bytes")
    rf = eng_f.cost.total("kv_read_bytes")
    rq = eng_q.cost.total("kv_read_bytes")
    assert wq * 4 == wf and rq * 4 == rf, (
        f"int8 KV bytes not exactly 0.25x: write {wq}/{wf}, "
        f"read {rq}/{rf}")
    # ---- pressure sub-run: equal byte budget, count preemptions ------
    probe = PoolConfig(
        n_layers=art.cfg.n_layers, n_pages=1, page_size=ecfg.page_size,
        n_kv_heads=art.cfg.n_kv_heads, head_dim=art.cfg.resolved_head_dim,
        dtype=art.cfg.dtype, kv_dtype="f32")
    budget = pressure_pages * probe.page_bytes
    probe_q = dataclasses.replace(probe, kv_dtype="int8")
    pages_f = pages_for_budget(probe, budget)
    pages_q = pages_for_budget(probe_q, budget)
    ecfg_pf = dataclasses.replace(ecfg_f, kv_pool_bytes=budget)
    ecfg_pq = dataclasses.replace(ecfg_q, kv_pool_bytes=budget)
    prep_f, _ = _serve(art, workload, "fcfs", False, ecfg_pf, clock)
    prep_q, _ = _serve(art, workload, "fcfs", False, ecfg_pq, clock)
    assert prep_f.n_preemptions >= 1, (
        f"pressure budget too loose: f32 never preempted "
        f"({pages_f} pages, {budget} bytes)")
    assert prep_q.n_preemptions < prep_f.n_preemptions, (
        f"int8 did not reduce preemptions at equal bytes: "
        f"{prep_q.n_preemptions} vs f32's {prep_f.n_preemptions}")
    emit("serving_quantization",
         rep_q.duration_s / max(rep_q.total_tokens, 1) * 1e6,
         f"kv_bytes_ratio={wq / wf};n_steps={rep_q.n_steps};"
         f"pages={pages_q}v{pages_f};"
         f"preempt={prep_q.n_preemptions}v{prep_f.n_preemptions}")
    print(f"# quantization pass: steps {rep_q.n_steps}=={rep_f.n_steps}, "
          f"kv bytes int8/f32 = {wq}/{wf} = {wq / wf}, "
          f"budget {budget}B -> {pages_q} int8 pages vs {pages_f} f32, "
          f"preemptions {prep_q.n_preemptions} vs {prep_f.n_preemptions}")
    return {
        # exact-gated: env-independent by construction (both dtypes run
        # in-process on the same workload; any environment drift shifts
        # the two runs together and the ratio stays pinned)
        "kv_bytes_ratio": wq / wf,
        "kv_read_bytes_ratio": rq / rf,
        "n_steps_delta": rep_q.n_steps - rep_f.n_steps,
        # reported, not gated (absolute values track text lengths)
        "n_steps": rep_f.n_steps,
        "kv_write_bytes": {"f32": wf, "int8": wq},
        "kv_read_bytes": {"f32": rf, "int8": rq},
        "pressure": {
            "budget_bytes": budget,
            "pages_f32": pages_f,
            "pages_int8": pages_q,
            "preemptions_f32": prep_f.n_preemptions,
            "preemptions_int8": prep_q.n_preemptions,
            # exact-gated boolean: the capacity claim itself
            "preempt_reduced": int(
                prep_q.n_preemptions < prep_f.n_preemptions),
        },
    }


def _chunked_pass(art, prompts, n_requests: int, ecfg, clock: str,
                  chunk: int):
    """Chunked-prefill TTFT-tail comparison on a head-of-line workload.

    A long prompt (the corpus prompt repeated until it dwarfs
    ``chunk``) arrives first, with a burst of short prompts right
    behind it. Monolithic prefill (``prefill_chunk=0``) ingests the
    whole long prompt inside the admission that precedes everyone
    else's first decode step, so every short request's first token
    waits behind all of its attention FLOPs. Chunked ingestion spreads
    the same prompt over decode steps and the short requests' compute-
    clock TTFT (``ttft_flops``, deterministic) drops — the p95 must
    strictly improve. The run also counts ``prefill_chunk`` trace
    spans to prove chunks actually interleaved with decode steps.
    """
    base = prompts[0]
    long_prompt = base
    tok = art.corpus.tokenizer
    while len(tok.encode(long_prompt)) < max(8 * chunk, 64):
        long_prompt = long_prompt + " " + base
    n_long = len(tok.encode(long_prompt))
    workload = [ServeRequest(prompt=long_prompt, plan=_plan("serial"),
                             arrival=0.0, deadline_s=30.0)]
    workload += [
        ServeRequest(prompt=prompts[(i + 1) % len(prompts)],
                     plan=_plan(SHAPES[i % len(SHAPES)]),
                     arrival=0.0, deadline_s=30.0)
        for i in range(n_requests - 1)]
    ecfg_mono = dataclasses.replace(ecfg, prefill_chunk=0)
    # tracing on the chunked run only, to count prefill_chunk spans
    # (tracing is passive, pinned by test_obs); the dumped trace gives
    # tools/check_trace.py real chunk spans to validate in CI
    trace_path = os.path.join(RESULTS, "serving_chunked_trace.jsonl")
    ecfg_chunk = dataclasses.replace(ecfg, prefill_chunk=chunk,
                                     trace=trace_path)
    rep_m, _ = _serve(art, workload, "fcfs", False, ecfg_mono, clock)
    rep_c, eng_c = _serve(art, workload, "fcfs", False, ecfg_chunk, clock)
    os.makedirs(RESULTS, exist_ok=True)
    jsonl_path, _ = eng_c.dump_trace()
    spans = [ev for ev in eng_c.obs.events
             if ev.get("ph") == "X" and ev.get("name") == "prefill_chunk"]
    chunk_steps = {ev["step"] for ev in spans}
    assert len(chunk_steps) >= 2, (
        f"long prompt ({n_long} tokens, chunk={chunk}) did not spread "
        f"over multiple steps: {sorted(chunk_steps)}")
    p95_m = rep_m.ttft_flops["p95"]
    p95_c = rep_c.ttft_flops["p95"]
    assert p95_c < p95_m, (
        f"chunked prefill did not improve the TTFT tail: "
        f"p95 {p95_c} flops chunked vs {p95_m} monolithic")
    emit("serving_chunked_prefill",
         rep_c.duration_s / max(rep_c.total_tokens, 1) * 1e6,
         f"ttft_flops_p95={p95_c:.0f}v{p95_m:.0f};"
         f"chunks={len(spans)};n_steps={rep_c.n_steps}v{rep_m.n_steps}")
    print(f"# chunked-prefill pass: long prompt {n_long} tok, "
          f"chunk={chunk}, {len(spans)} chunk spans over "
          f"{len(chunk_steps)} steps; ttft_flops p95 "
          f"{p95_c:.0f} (chunked) vs {p95_m:.0f} (monolithic), "
          f"mean {rep_c.ttft_flops['mean']:.0f} vs "
          f"{rep_m.ttft_flops['mean']:.0f}")
    return {
        # exact-gated boolean: the head-of-line claim itself
        "improved": int(p95_c < p95_m),
        "jsonl": os.path.relpath(jsonl_path),
        # reported, not gated (track text lengths / workload shape)
        "chunk": chunk,
        "long_prompt_tokens": n_long,
        "n_chunk_spans": len(spans),
        "n_chunk_steps": len(chunk_steps),
        "ttft_flops_p95": {"monolithic": p95_m, "chunked": p95_c},
        "ttft_flops_mean": {"monolithic": rep_m.ttft_flops["mean"],
                            "chunked": rep_c.ttft_flops["mean"]},
        "n_steps": {"monolithic": rep_m.n_steps,
                    "chunked": rep_c.n_steps},
    }


def run(art=None, n_requests: int = 16, rate: float = 4.0,
        smoke: bool = False):
    clock = "wall"
    if smoke:
        # CI configuration: the step clock makes the gated step metrics
        # (n_steps, ttft_steps) exactly reproducible across machines —
        # seeded Poisson arrivals in decode steps, no wall time anywhere
        # in the schedule. 0.5 req/step staggers 6 arrivals over ~12
        # steps, the same early-arrival profile the wall config gives on
        # a typical CPU. Wall-clock metrics are still reported but have
        # no cross-machine meaning here (and are not gated).
        n_requests, rate, clock = 6, 0.5, "step"
    art = art or get_artifacts()
    prompts = [p for p, _, _, _ in eval_prompts(art.corpus, n=8)]
    ecfg = default_engine_cfg(
        max_slots=8, n_pages=4096,
        max_step_tokens=4 if smoke else 12,
        max_conclusion_tokens=4 if smoke else 16)
    workload = make_workload(prompts, n_requests, rate)
    runs = [("fcfs", False), ("chain-aware", False), ("fcfs", True)]
    reports = {}
    for policy, closed in runs:
        tag = f"{policy}{'-closed' if closed else ''}"
        t0 = time.time()
        rep, _ = _serve(art, workload, policy, closed, ecfg, clock)
        reports[tag] = rep.to_dict()
        emit(f"serving_{tag}",
             rep.duration_s / max(rep.total_tokens, 1) * 1e6,
             f"tput={rep.throughput_tok_s:.1f}tok_s;"
             f"ttft_ms={rep.ttft_s['mean']*1e3:.0f};"
             f"ttft_steps={rep.ttft_steps['mean']:.1f};"
             f"tpot_ms={rep.tpot_s['mean']*1e3:.1f};"
             f"goodput={rep.goodput:.2f};"
             f"preempt={rep.n_preemptions}")
        print(f"# {rep.summary()} ({time.time()-t0:.1f}s)")
        assert rep.n_completed == n_requests, (
            f"{tag}: {rep.n_completed}/{n_requests} completed")
    # continuous batching must not lose to the closed-batch baseline on
    # time-to-first-token (compared in decode steps — deterministic and
    # immune to first-run compilation noise in wall time)
    if reports["fcfs"]["ttft_steps"]["mean"] > reports["fcfs-closed"][
            "ttft_steps"]["mean"]:
        print("# WARNING: continuous TTFT did not beat closed batch")
    # one traced fcfs pass: proves tracing is passive (identical step
    # count) and produces the deterministic event-count section the
    # regression gate diffs, plus the Perfetto-loadable trace artifact.
    # KV dtype pinned to f32 here so the exact-gated trace.cost byte
    # totals match one committed baseline on every kv-dtype matrix leg;
    # the int8 byte accounting is gated through the quantization
    # section's exact 0.25 ratios instead.
    ecfg_traced = dataclasses.replace(ecfg, kv_dtype="f32")
    rep_traced_ref, _ = ((reports["fcfs"], None)
                         if ecfg.kv_dtype == "f32" else
                         _serve(art, workload, "fcfs", False, ecfg_traced,
                                clock))
    if not isinstance(rep_traced_ref, dict):
        rep_traced_ref = rep_traced_ref.to_dict()
    trace_section = _traced_pass(art, workload, ecfg_traced, clock,
                                 rep_traced_ref)
    # verified-serving pass: stage-typed plans, audit trail on
    verified_section = _verified_pass(art, prompts, n_requests, rate,
                                      ecfg, clock)
    # quantization pass: int8-vs-f32 parity + exact byte ratios + equal-
    # byte-budget preemption pressure (dtypes pinned internally)
    quant_section = _quantization_pass(
        art, workload, ecfg, clock,
        pressure_pages=24 if smoke else 48)
    # chunked-prefill pass: head-of-line long prompt, TTFT-in-flops tail
    chunked_section = _chunked_pass(
        art, prompts, n_requests, ecfg, clock,
        chunk=8 if smoke else 16)
    os.makedirs(RESULTS, exist_ok=True)
    out = {"config": {"n_requests": n_requests, "rate": rate,
                      "clock": clock, "max_slots": ecfg.max_slots,
                      "attention_backend": ecfg.attention_backend,
                      "kv_dtype": ecfg.kv_dtype,
                      "shapes": SHAPES,
                      "staged_shapes": STAGED_SHAPES},
           "runs": reports,
           "trace": trace_section,
           "verified": verified_section,
           "quantization": quant_section,
           "chunked_prefill": chunked_section}
    path = os.path.join(RESULTS, "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {os.path.relpath(path)}")
    return reports


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=4.0)
    args = ap.parse_args()
    run(n_requests=args.requests, rate=args.rate, smoke=args.smoke)
