"""Table 3: speedup and accuracy by DAG topology class.

Paper: linear 1.00x (3% of cases), multiple independent chains 1.40x
(58%), complex intersecting 1.25x (39%). We report measured speedups
per class plus the class proportions of the synthetic corpus, and the
structural latency bound (critical-path tokens / total tokens).
"""

from __future__ import annotations

import time
from collections import defaultdict

from .common import default_engine_cfg, emit, get_artifacts
from repro.engine import MedVerseEngine, SerialEngine


def run(art=None, n_per_class: int = 4):
    art = art or get_artifacts()
    tok = art.corpus.tokenizer
    by_class = defaultdict(list)
    for ex in art.corpus.train + art.corpus.eval:
        by_class[ex.topology].append(ex)
    n_total = sum(len(v) for v in by_class.values())
    eng = MedVerseEngine(art.params_mask, art.cfg, tok,
                         default_engine_cfg())
    sere = SerialEngine(art.params_auto, art.cfg, tok, default_engine_cfg())
    warm = art.corpus.eval[0]
    wopts = " ".join(f"{l} ) {o}" for l, o in zip("abcd", warm.options))
    wp = f"{warm.question} Options : {wopts}"
    eng.generate([wp], plans=[warm.prefix_text[len(wp):].strip()])
    sere.generate([wp], max_tokens=8)
    rows = []
    for topo_class in ("single_linear_chain", "multiple_independent_chains",
                       "complex_intersecting"):
        exs = by_class.get(topo_class, [])[:n_per_class]
        prop = 100 * len(by_class.get(topo_class, [])) / max(n_total, 1)
        if not exs:
            emit(f"table3_{topo_class}", 0.0, f"prop={prop:.0f}%;absent")
            continue
        par = ser = 0.0
        crit_ratio = 0.0
        for ex in exs:
            opts = " ".join(f"{l} ) {o}" for l, o in zip("abcd", ex.options))
            prompt = f"{ex.question} Options : {opts}"
            plan = ex.prefix_text[len(prompt):].strip()
            t0 = time.monotonic()
            r = eng.generate([prompt], plans=[plan])[0]
            par += time.monotonic() - t0
            crit_ratio += r.critical_path_tokens / max(r.n_tokens, 1)
            t0 = time.monotonic()
            sere.generate([prompt], max_tokens=r.n_tokens)
            ser += time.monotonic() - t0
        speedup = ser / max(par, 1e-9)
        rows.append((topo_class, prop, speedup))
        emit(f"table3_{topo_class}", par / len(exs) * 1e6,
             f"prop={prop:.0f}%;speedup={speedup:.2f}x;"
             f"crit_frac={crit_ratio/len(exs):.2f}")
    return rows


if __name__ == "__main__":
    run()
