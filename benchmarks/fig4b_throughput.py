"""Figure 4b: iso-length throughput vs sequence length (batch 1).

Paper: serial AR throughput stays flat (~10 tok/s on H200) while
MedVerse's parallel decode converts idle compute into token throughput,
widening with length (+69.3% at 2048). We reproduce the *shape* of the
curve on CPU: tokens/sec for generating N tokens as (a) one serial
stream vs (b) W parallel frontier streams of N/W tokens each (the
engine's fork path), N swept over lengths.
"""

from __future__ import annotations

import dataclasses
import time

from .common import default_engine_cfg, emit, get_artifacts
from repro.core.plan import OutlineStep, ReasoningPlan
from repro.engine import MedVerseEngine, SerialEngine


def synth_plan(width: int) -> str:
    steps = tuple(
        OutlineStep(index=i + 1, label=f"q -> Outcome-{i:02d}",
                    dependencies=())
        for i in range(width)
    )
    return ("<Think> parallel probe </Think> "
            + ReasoningPlan(steps=steps).serialize())


def run(art=None, lengths=(64, 128, 256, 512), width: int = 8):
    art = art or get_artifacts()
    tok = art.corpus.tokenizer
    prompt = "A patient has Thyrotoxicosis . Options : a ) Potassium-iodide"
    rows = []
    for n in lengths:
        per_step = max(n // width, 4)
        ecfg = default_engine_cfg(
            plan_override=synth_plan(width), max_slots=width,
            max_step_tokens=per_step, max_conclusion_tokens=4,
            max_chain_len=2 * n + 256, n_pages=16384)
        eng = MedVerseEngine(art.params_mask, art.cfg, tok, ecfg)
        t0 = time.monotonic()
        r = eng.generate([prompt])[0]
        par_dt = time.monotonic() - t0
        par_tput = r.n_tokens / par_dt
        # async-frontier parity check (paper: "parallel execution without
        # additional overhead" — on a pure fan-out plan the per-transition
        # scheduler should match the synchronized path)
        eng = MedVerseEngine(art.params_mask, art.cfg, tok,
                             dataclasses.replace(ecfg, async_frontier=True))
        t0 = time.monotonic()
        ra = eng.generate([prompt])[0]
        async_dt = time.monotonic() - t0
        async_tput = ra.n_tokens / async_dt
        ser = SerialEngine(art.params_auto, art.cfg, tok,
                           default_engine_cfg(max_chain_len=2 * n + 256))
        t0 = time.monotonic()
        s = ser.generate([prompt], max_tokens=r.n_tokens)[0]
        ser_dt = time.monotonic() - t0
        ser_tput = s.n_tokens / ser_dt
        gain = (par_tput / ser_tput - 1) * 100
        rows.append((n, ser_tput, par_tput, async_tput, gain))
        emit(f"fig4b_throughput_len{n}", par_dt / max(r.n_tokens, 1) * 1e6,
             f"par_tok_s={par_tput:.1f};async_tok_s={async_tput:.1f};"
             f"ser_tok_s={ser_tput:.1f};gain={gain:+.1f}%")
    return rows


if __name__ == "__main__":
    run()
