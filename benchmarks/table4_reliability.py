"""Table 4: clinical reliability — KG-grounded judge (the paper uses a
GPT-5.2 physician-judge; ours is the knowledge graph itself, which is
stricter and deterministic).

Metrics per generated reasoning trace:
  edge_accuracy   % of generated step edges present in the KG
  logical_jumps   avg count of steps whose claimed edge is NOT in the KG
  high_risk       % of cases whose final answer is not a valid treatment
                  for the queried disease (guideline contradiction proxy)

Paper deltas (MedVerse vs serial): edge accuracy +15.4%, jumps -25.5%,
high-risk errors -50%.
"""

from __future__ import annotations

import re

from .common import default_engine_cfg, emit, eval_prompts, get_artifacts

_DISEASE_RE = re.compile(r"(?:A patient has|The diagnosis is)\s+([\w\-]+)")


def _disease_of(ex):
    ents = getattr(ex, "question_entities", None)
    if ents:
        return ents[0]
    m = _DISEASE_RE.search(ex.question)
    return m.group(1) if m else ""
from repro.data.knowledge_graph import build_kg
from repro.engine import MedVerseEngine, SerialEngine

_EDGE_RE = re.compile(r"Transient Step \d+\s*:\s*([\w\-, ]+?)->\s*([\w\-]+)")


def judge(text: str, kg, disease_hint: str = ""):
    edges = []
    for m in _EDGE_RE.finditer(text):
        tgt = m.group(2).strip()
        for src in m.group(1).split(","):
            src = src.strip()
            if src:
                edges.append((src, tgt))
    if not edges:
        return 0.0, 0.0
    ok = sum(kg.has_edge(a, b) for a, b in edges)
    return ok / len(edges), len(edges) - ok


def run(art=None, n: int = 16):
    art = art or get_artifacts()
    kg = build_kg(48, seed=0)  # same seed as Corpus.build default
    tok = art.corpus.tokenizer
    prompts = eval_prompts(art.corpus, n)
    exs = art.corpus.eval[:n]
    rows = {}
    for tag, make in (
        ("serial", lambda: SerialEngine(art.params_auto, art.cfg, tok,
                                        default_engine_cfg())),
        ("medverse", lambda: MedVerseEngine(art.params_mask, art.cfg, tok,
                                            default_engine_cfg(max_slots=8))),
    ):
        eng = make()
        if tag == "serial":
            rs = eng.generate([p for p, _, _, _ in prompts], max_tokens=220)
        else:
            rs = eng.generate([p for p, _, _, _ in prompts])
        edge_accs, jumps, risky = [], [], 0
        for r, ex in zip(rs, exs):
            ea, j = judge(r.text, kg)
            edge_accs.append(ea)
            jumps.append(j)
            m = re.search(r"Answer\s*:\s*[a-d]\s*\)\s*([\w\-]+)", r.text)
            ans_entity = m.group(1) if m else None
            disease = _disease_of(ex)
            valid = {e.dst for e in kg.out.get(disease, [])
                     if e.rel == "treated_by"}
            risky += int(ans_entity is None or ans_entity not in valid)
        rows[tag] = (sum(edge_accs) / n, sum(jumps) / n, 100 * risky / n)
        emit(f"table4_{tag}", 0.0,
             f"edge_acc={rows[tag][0]:.3f};logical_jumps={rows[tag][1]:.2f};"
             f"high_risk_pct={rows[tag][2]:.1f}")
    return rows


if __name__ == "__main__":
    run()
