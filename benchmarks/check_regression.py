"""CI bench-regression gate: diff ``results/BENCH_*.json`` against the
committed baselines in ``benchmarks/baselines/`` with per-metric
tolerances.

Usage (after ``PYTHONPATH=src python benchmarks/run.py --smoke``)::

    python benchmarks/check_regression.py              # gate (exit 1 on fail)
    python benchmarks/check_regression.py --update-baselines

Metric selection policy: only machine-independent quantities are
gated — deterministic step/count metrics (tight tolerances) and
same-machine *ratios* (e.g. the paged vs dense decode speedup), which
cancel machine speed. Raw wall-clock numbers are recorded and uploaded
as artifacts but never gated: CI runners are noisy and heterogeneous.
Refreshing after an intentional perf change: re-run the smoke suite,
then commit the files ``--update-baselines`` copies over (see README
"CI").
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from dataclasses import dataclass
from typing import List

HERE = os.path.dirname(os.path.abspath(__file__))
RESULTS = os.path.join(HERE, "..", "results")
BASELINES = os.path.join(HERE, "baselines")


@dataclass
class Metric:
    path: str          # dotted path into the JSON document
    higher_better: bool
    rel_tol: float     # fraction of regression tolerated vs baseline
    # lower-is-better only: absolute slack added to the limit so a 0.0
    # baseline (e.g. max_abs_err on the authoring machine) doesn't
    # collapse the relative tolerance to an exact-zero requirement —
    # reduction order differs by ulps across BLAS/XLA versions
    abs_floor: float = 0.0
    # exact metrics must match the baseline bit-for-bit in either
    # direction (improvements included): the analytic cost counters are
    # integers computed from the schedule, so *any* drift means the
    # schedule changed and the baseline must be refreshed deliberately
    exact: bool = False
    # where the number comes from — which bench pass computes it and
    # from what inputs. Printed on failure so a red gate names its
    # source instead of just a dotted JSON path.
    provenance: str = ""

    def check(self, base: float, new: float):
        """(ok, threshold) — fail only on regression beyond rel_tol;
        improvements never fail (except ``exact``, which pins both
        directions)."""
        if self.exact:
            return new == base, base
        if self.higher_better:
            thr = base * (1.0 - self.rel_tol)
            return new >= thr, thr
        thr = max(base * (1.0 + self.rel_tol), self.abs_floor)
        return new <= thr, thr


# shared provenance for the analytic cost counters (see repro/obs/cost
# .py): every number is an integer computed from the dispatched
# schedule (bucket widths, page runs, GQA geometry), never a clock.
_COST_PROV = ("engine CostLedger totals (repro.obs.cost), emitted by "
              "serving_bench._traced_pass over the fcfs smoke workload "
              "with kv_dtype pinned to f32")

# file -> gated metrics. Only machine-independent quantities are gated:
# step/count metrics are deterministic on a given commit, and the
# paged-vs-dense speedup is a same-machine ratio (both tiers timed in
# the same process, so runner speed cancels). Raw wall-clock (us,
# tok/s) is recorded in the JSON and uploaded as an artifact but never
# gated — CI runners are noisy and heterogeneous.
SPECS = {
    "BENCH_kernel.json": [
        # wide tolerance: the ratio cancels uniform runner speed but not
        # machine *class* (core count, cache, BLAS threading), so it
        # gates gross inversions (paged collapsing to ~half of dense),
        # not the margin. If CI's runner class disagrees with a locally
        # authored baseline, refresh from the bench-regression artifact
        # of a green main run (README "CI").
        Metric("paged_decode.speedup_xla_vs_dense", True, 0.50),
        Metric("paged_decode.max_abs_err", False, 9.0, abs_floor=1e-5),
    ],
    "BENCH_serving.json": [
        Metric("runs.fcfs.n_completed", True, 0.0),
        Metric("runs.fcfs.goodput", True, 0.0),
        Metric("runs.fcfs.ttft_steps.mean", False, 0.60),
        Metric("runs.chain-aware.ttft_steps.mean", False, 0.60),
        # deterministic throughput proxy: total scheduler steps to
        # drain the fixed smoke workload (more steps = fewer tokens
        # retired per step). Deterministic because the smoke serving
        # bench runs on the scheduler's *step* clock (seeded arrivals
        # in decode steps, no wall time in the schedule); the slack
        # absorbs token-level drift across jax/BLAS versions only.
        Metric("runs.fcfs.n_steps", False, 0.10),
        # traced pass (smoke only): the bench re-runs the fcfs workload
        # with EngineConfig.trace on and asserts in-process that the
        # step count is identical (tracing is passive). Event counts on
        # the step clock are deterministic on a given commit; the
        # two-sided band (higher+lower on the same path) pins them
        # against silent instrumentation loss or runaway emission,
        # with slack for token-level drift across jax/BLAS versions.
        Metric("trace.span_problems", False, 0.0),
        # >= 2 DAG transitions of one request decoding on the same
        # step — the paper's parallel-frontier claim, gated directly.
        # Baseline is 4 (the wide fan-out shape); 50% slack keeps the
        # floor at 2, the minimum that still proves parallel execution
        Metric("trace.max_overlap", True, 0.50),
        Metric("trace.n_events", True, 0.15),
        Metric("trace.n_events", False, 0.15),
        Metric("trace.event_counts.B:stream", True, 0.10),
        Metric("trace.event_counts.B:stream", False, 0.10),
        # analytic cost model (repro.obs.cost): exact integers computed
        # from the dispatched schedule — bucket widths, page runs, GQA
        # geometry — never from a device clock, so they are pinned
        # bit-for-bit. Any change (either direction) means the engine
        # does different work per token and must be an explicit,
        # reviewed baseline refresh. This is the gate every perf PR
        # (int8 KV, chunked prefill, cascade attention) is judged by.
        Metric("trace.cost.prefill_attn_flops", False, 0.0, exact=True,
               provenance=_COST_PROV),
        Metric("trace.cost.decode_attn_flops", False, 0.0, exact=True,
               provenance=_COST_PROV),
        Metric("trace.cost.spec_verify_attn_flops", False, 0.0,
               exact=True, provenance=_COST_PROV),
        Metric("trace.cost.kv_read_bytes", False, 0.0, exact=True,
               provenance=_COST_PROV),
        # kv_write_bytes and page_gathers are banded, not exact: both
        # track *which* pages the radix cache adopts vs writes, and
        # radix adoption follows generated token ids — temp-0 argmax
        # tie-breaks shift across jax/BLAS versions, so these two
        # drifted environmentally at the PR-9 HEAD while the pure-
        # geometry counters (flops, useful/padded pairs) stayed pinned.
        # Two-sided 10% band: catches accounting bugs (a missed or
        # double-counted write is a >=2x jump at smoke scale) without
        # going red on an ulp-level tie-break. The *exact* int8 byte
        # claim lives in quantization.kv_bytes_ratio below, which is a
        # same-process ratio and immune to this drift.
        Metric("trace.cost.kv_write_bytes", True, 0.10,
               provenance=_COST_PROV + "; banded (radix-adoption-"
               "sensitive, see comment)"),
        Metric("trace.cost.kv_write_bytes", False, 0.10,
               provenance=_COST_PROV + "; banded (radix-adoption-"
               "sensitive, see comment)"),
        Metric("trace.cost.page_gathers", True, 0.10,
               provenance=_COST_PROV + "; banded (radix-adoption-"
               "sensitive, see comment)"),
        Metric("trace.cost.page_gathers", False, 0.10,
               provenance=_COST_PROV + "; banded (radix-adoption-"
               "sensitive, see comment)"),
        Metric("trace.cost.useful_kv", False, 0.0, exact=True,
               provenance=_COST_PROV),
        Metric("trace.cost.padded_kv", False, 0.0, exact=True,
               provenance=_COST_PROV),
        Metric("trace.cost.padded_rows", False, 0.0, exact=True,
               provenance=_COST_PROV),
        Metric("trace.cost.compiles", False, 0.0, exact=True,
               provenance="CompileWatcher static-shape-key count, "
               "serving_bench traced fcfs pass"),
        # the bucket-ladder invariant: no XLA compile after warmup,
        # enforced as == 0 (baseline is 0, exact match required; the
        # bench additionally asserts this in-process)
        Metric("trace.cost.recompiles_after_warmup", False, 0.0,
               exact=True),
        # verified-serving pass (stage-typed plans, audit trail on).
        # The rule-based verdict extractor is deterministic at temp 0,
        # so decision/verdict/disposition tallies and the per-step
        # verified rate are exact integers/ratios of the schedule —
        # pinned bit-for-bit like the cost counters. n_steps gets the
        # same 10% band as the latency passes (token-level drift across
        # jax/BLAS versions); the bench asserts audit passivity
        # (identical step count audited vs unaudited) in-process.
        Metric("verified.n_steps", False, 0.10),
        Metric("verified.n_audit_records", False, 0.0, exact=True),
        Metric("verified.verdicts.pass", False, 0.0, exact=True),
        Metric("verified.verdicts.fail", False, 0.0, exact=True),
        Metric("verified.verdicts.abstain", False, 0.0, exact=True),
        Metric("verified.n_verified", False, 0.0, exact=True),
        Metric("verified.verified_per_step", False, 0.0, exact=True),
        Metric("verified.critic_priority_events", False, 0.0,
               exact=True),
        Metric("verified.span_problems", False, 0.0),
        # quantization pass: int8-vs-f32 KV pages, dtypes pinned inside
        # the pass so this section is identical on every kv-dtype CI
        # matrix leg. The ratios are same-process (numerator and
        # denominator from the same run pair, so environmental token
        # drift shifts both together) and pinned bit-for-bit: int8
        # stores 1 byte per f32's 4, exactly 0.25, no rounding anywhere
        # in the analytic accounting.
        Metric("quantization.kv_bytes_ratio", False, 0.0, exact=True,
               provenance="int8/f32 kv_write_bytes CostLedger ratio, "
               "serving_bench._quantization_pass (dtype-pinned pair "
               "run; must be exactly 0.25)"),
        Metric("quantization.kv_read_bytes_ratio", False, 0.0,
               exact=True,
               provenance="int8/f32 kv_read_bytes CostLedger ratio, "
               "serving_bench._quantization_pass (must be exactly "
               "0.25)"),
        # temp-0 parity: int8 dequant must not change a single argmax,
        # so the step counts of the two runs are identical (delta 0)
        Metric("quantization.n_steps_delta", False, 0.0, exact=True,
               provenance="int8 minus f32 scheduler step count, "
               "serving_bench._quantization_pass (temp-0 parity)"),
        # equal-byte-budget capacity claim: int8 preempts strictly less
        # (1 = reduced; raw preemption counts are reported ungated)
        Metric("quantization.pressure.preempt_reduced", True, 0.0,
               exact=True,
               provenance="serving_bench._quantization_pass pressure "
               "sub-run: both dtypes at kv_pool_bytes sized to force "
               "f32 preemptions; 1 iff int8 preempted strictly less"),
        Metric("quantization.pressure.pages_f32", False, 0.0,
               exact=True,
               provenance="pages_for_budget(PoolConfig f32) at the "
               "pressure byte budget — pure layout arithmetic"),
        Metric("quantization.pressure.pages_int8", False, 0.0,
               exact=True,
               provenance="pages_for_budget(PoolConfig int8) at the "
               "pressure byte budget — pure layout arithmetic"),
        # chunked-prefill pass: compute-clock TTFT tail (attention
        # FLOPs from arrival to first token). 1 iff chunked ingestion
        # strictly improved the p95 over monolithic prefill on the
        # head-of-line workload; absolute flops are reported ungated.
        Metric("chunked_prefill.improved", True, 0.0, exact=True,
               provenance="serving_bench._chunked_pass: ttft_flops p95 "
               "(RequestMetrics compute clock) chunked < monolithic "
               "on the long-prompt burst workload"),
    ],
    "BENCH_spec.json": [
        # all step/count metrics: deterministic on a given commit (the
        # bench runs temp-0, one request at a time, no wall clock in
        # any gated number); slack absorbs token-level drift across
        # jax/BLAS versions only
        Metric("runs.off.decode_iters", False, 0.10),
        Metric("runs.ngram.decode_iters", False, 0.15),
        Metric("runs.radix.decode_iters", False, 0.15),
        # acceptance floors: the bench asserts strict iteration wins
        # in-process; these gate drafter *quality* (ngram ~0.89 at the
        # committed baseline — 10% slack keeps the ISSUE's 0.82 floor;
        # radix replays the cache, 1.00 by construction)
        Metric("runs.ngram.acceptance", True, 0.10),
        Metric("runs.radix.acceptance", True, 0.02),
    ],
}

# file -> dotted paths that must be *equal* between baseline and
# results before any metric is diffed: catches comparing a full-shape
# run (`kernel_bench.py` without --smoke) against the committed smoke
# baseline, or a changed serving workload.
GUARDS = {
    "BENCH_kernel.json": ["config.smoke", "paged_decode.shape"],
    # config.kv_dtype is recorded but deliberately NOT a guard: the
    # int8 CI matrix leg runs the same workload with $ENGINE_KV_DTYPE=
    # int8, and every gated section is either dtype-pinned inside the
    # bench (trace.cost runs f32; quantization/chunked pin their own
    # dtypes) or dtype-invariant by temp-0 parity (runs.*, verified.*)
    # — so one committed f32 baseline gates both legs.
    "BENCH_serving.json": ["config.n_requests", "config.rate",
                           "config.clock", "config.max_slots",
                           "config.attention_backend"],
    "BENCH_spec.json": ["config.n_requests", "config.n_unique",
                        "config.draft_len", "config.max_slots"],
}


def _lookup_raw(doc: dict, path: str):
    cur = doc
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise KeyError(path)
        cur = cur[part]
    return cur


def _lookup(doc: dict, path: str) -> float:
    return float(_lookup_raw(doc, path))


# intrinsic workload requirements a committed baseline must satisfy —
# the configuration CI's smoke run produces. update_baselines refuses
# anything else, so a full-shape or wall-clock local run can't be
# installed as a baseline the gate would then reject on every CI run.
EXPECTED = {
    "BENCH_kernel.json": {"config.smoke": True},
    "BENCH_serving.json": {"config.clock": "step"},
    "BENCH_spec.json": {"config.smoke": True},
}


def update_baselines() -> int:
    os.makedirs(BASELINES, exist_ok=True)
    errors = []
    for fname in SPECS:
        src = os.path.join(RESULTS, fname)
        if not os.path.exists(src):
            errors.append(f"{fname}: missing — run the smoke bench first "
                          f"(`PYTHONPATH=src python benchmarks/run.py "
                          f"--smoke`)")
            continue
        with open(src) as f:
            doc = json.load(f)
        bad = []
        for path, want in EXPECTED.get(fname, {}).items():
            try:
                got = _lookup_raw(doc, path)
            except KeyError:
                got = "<missing>"
            if got != want:
                bad.append(f"{path}={got!r} (want {want!r})")
        if bad:
            errors.append(f"{fname}: not a smoke-workload result — "
                          f"{'; '.join(bad)} — re-run the *smoke* bench "
                          f"before refreshing baselines")
            continue
        shutil.copyfile(src, os.path.join(BASELINES, fname))
        print(f"baseline updated: benchmarks/baselines/{fname}")
    if errors:
        print("ERROR:")
        for e in errors:
            print(f"  {e}")
        return 1
    return 0


def check() -> int:
    failures: List[str] = []
    rows = []
    for fname, metrics in SPECS.items():
        bpath = os.path.join(BASELINES, fname)
        rpath = os.path.join(RESULTS, fname)
        if not os.path.exists(bpath):
            failures.append(
                f"{fname}: no committed baseline — run the smoke bench and "
                f"`python benchmarks/check_regression.py --update-baselines`")
            continue
        if not os.path.exists(rpath):
            failures.append(f"{fname}: results/{fname} missing — did the "
                            f"bench run?")
            continue
        with open(bpath) as f:
            base_doc = json.load(f)
        with open(rpath) as f:
            new_doc = json.load(f)
        mismatched = False
        for g in GUARDS.get(fname, []):
            try:
                nv = _lookup_raw(new_doc, g)
            except KeyError:
                failures.append(
                    f"{fname}: config guard {g} missing from results")
                mismatched = True
                continue
            try:
                bv = _lookup_raw(base_doc, g)
            except KeyError:
                # additive-safe: a guard the committed baseline predates
                # (a new config field) can't indicate a workload switch;
                # it starts gating once baselines are refreshed
                rows.append(f"  {'new':>10}  {fname}:{g} not in baseline "
                            f"yet (results: {nv!r}) — skipped")
                continue
            if bv != nv:
                failures.append(
                    f"{fname}:{g}: results were produced with a different "
                    f"workload than the baseline ({nv!r} vs {bv!r}) — run "
                    f"the *smoke* bench (`benchmarks/run.py --smoke`) "
                    f"before gating or refreshing baselines")
                mismatched = True
        if mismatched:
            continue
        for m in metrics:
            try:
                base = _lookup(base_doc, m.path)
            except KeyError:
                # additive-safe: a newly gated metric the committed
                # baseline predates is reported, not failed — it starts
                # gating once baselines are refreshed with the new field
                rows.append(f"  {'new':>10}  {fname}:{m.path} not in "
                            f"baseline yet — skipped (refresh baselines "
                            f"to gate it)")
                continue
            try:
                new = _lookup(new_doc, m.path)
            except KeyError:
                failures.append(f"{fname}:{m.path}: missing from results")
                continue
            ok, thr = m.check(base, new)
            arrow = "=" if m.exact else ("↑" if m.higher_better else "↓")
            status = "ok" if ok else "REGRESSION"
            tol = "exact" if m.exact else f"tol {m.rel_tol:.0%}"
            rows.append(f"  {status:>10}  {fname}:{m.path} {arrow} "
                        f"base={base:.4g} new={new:.4g} "
                        f"({tol}, limit {thr:.4g})")
            if not ok:
                detail = ("exact metric drifted — the schedule changed; "
                          "refresh baselines deliberately if intended"
                          if m.exact else
                          f"worse than {m.rel_tol:.0%} tolerance, "
                          f"limit {thr:.4g}")
                prov = (f"\n      provenance: {m.provenance}"
                        if m.provenance else "")
                failures.append(
                    f"{fname}:{m.path}: {new:.4g} vs baseline {base:.4g} "
                    f"({detail}){prov}")
    print("bench-regression report:")
    for r in rows:
        print(r)
    if failures:
        print("\nFAILED:")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print("\nall gated metrics within tolerance")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update-baselines", action="store_true",
                    help="copy current results/BENCH_*.json into "
                         "benchmarks/baselines/ (commit the result)")
    args = ap.parse_args()
    sys.exit(update_baselines() if args.update_baselines else check())


if __name__ == "__main__":
    main()
