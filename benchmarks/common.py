"""Shared benchmark harness: synthetic corpus + trained model variants
(Auto = causal-trained, Mask = MedVerse-attention-trained), cached on
disk so every table/figure benchmark reuses the same artifacts."""

from __future__ import annotations

import dataclasses
import json
import os
import pickle
import re
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs import get_config
from repro.data import Corpus, Tokenizer, encode_example
from repro.engine import EngineConfig, MedVerseEngine, SerialEngine
from repro.models import init_params
from repro.models.config import ATTN, ModelConfig
from repro.train import TrainConfig, train_model

CACHE = os.path.join(os.path.dirname(__file__), "..", "results", "cache")


def bench_model_config(vocab_size: int, name: str = "bench") -> ModelConfig:
    return ModelConfig(
        name=name,
        arch_type="dense",
        vocab_size=vocab_size,
        d_model=192,
        n_layers=4,
        n_heads=4,
        n_kv_heads=2,
        d_ff=512,
        head_dim=48,
        pattern_unit=(ATTN,),
        rope_theta=10_000.0,
        dtype="float32",
        scan_layers=False,
        remat=False,
        max_seq_len=1024,
    )


@dataclasses.dataclass
class Artifacts:
    corpus: Corpus
    cfg: ModelConfig
    params_mask: dict
    params_auto: dict
    history_mask: list
    history_auto: list


def _cache_path(tag: str) -> str:
    os.makedirs(CACHE, exist_ok=True)
    return os.path.join(CACHE, tag + ".pkl")


def get_artifacts(n_items: int = 400, epochs: int = 4,
                  seed: int = 0, tag: str = "default",
                  force: bool = False) -> Artifacts:
    path = _cache_path(f"artifacts_{tag}")
    if os.path.exists(path) and not force:
        with open(path, "rb") as f:
            return pickle.load(f)
    corpus = Corpus.build(n_items=n_items, n_clusters=48, seed=seed)
    cfg = bench_model_config(corpus.tokenizer.vocab_size + 64)
    t0 = time.time()
    params_mask, hist_m = train_model(
        cfg, corpus, TrainConfig(epochs=epochs, batch_size=8, seq_len=256,
                                 causal=False, seed=seed))
    params_auto, hist_a = train_model(
        cfg, corpus, TrainConfig(epochs=epochs, batch_size=8, seq_len=256,
                                 causal=True, seed=seed))
    print(f"# trained mask+auto variants in {time.time()-t0:.0f}s "
          f"(final ce mask={hist_m[-1]['ce']:.3f} auto={hist_a[-1]['ce']:.3f})")
    art = Artifacts(corpus=corpus, cfg=cfg, params_mask=params_mask,
                    params_auto=params_auto, history_mask=hist_m,
                    history_auto=hist_a)
    with open(path, "wb") as f:
        pickle.dump(art, f)
    return art


def eval_prompts(corpus: Corpus, n: Optional[int] = None):
    """(prompt, gold_letter, plan_text, topology) per eval example."""
    out = []
    for ex in corpus.eval[: n or len(corpus.eval)]:
        opts = " ".join(f"{l} ) {o}" for l, o in zip("abcd", ex.options))
        prompt = f"{ex.question} Options : {opts}"
        think_plan = ex.prefix_text[len(prompt):].strip()
        out.append((prompt, ex.answer_letter, think_plan, ex.topology))
    return out


_ANSWER_RE = re.compile(r"Answer\s*:\s*([a-d])\s*\)")


def extract_answer(text: str) -> Optional[str]:
    m = _ANSWER_RE.search(text)
    return m.group(1) if m else None


def accuracy(results, golds) -> float:
    ok = 0
    for r, g in zip(results, golds):
        a = extract_answer(r.text)
        ok += int(a == g)
    return ok / max(len(golds), 1)


def default_engine_cfg(**kw) -> EngineConfig:
    base = dict(max_slots=8, page_size=16, n_pages=8192,
                max_chain_len=512, max_plan_tokens=200,
                max_step_tokens=24, max_conclusion_tokens=32,
                max_serial_tokens=256)
    base.update(kw)
    return EngineConfig(**base)


def emit(name: str, us_per_call: float, derived: str) -> None:
    """CSV line per the harness contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")
