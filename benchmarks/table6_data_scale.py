"""Table 6: training-data scaling — accuracy vs corpus fraction.

Paper: monotone improvement 1k->14k with ~95% of peak at ~36% of data.
We train on {25%, 50%, 100%} of the synthetic corpus and evaluate
Mask-Par accuracy + plan validity on the shared eval set.
"""

from __future__ import annotations

from .common import (
    accuracy,
    default_engine_cfg,
    emit,
    eval_prompts,
    get_artifacts,
)
from repro.engine import MedVerseEngine
from repro.train import TrainConfig, train_model


def run(art=None, fractions=(0.25, 0.5, 1.0), epochs: int = 6, n_eval: int = 16):
    art = art or get_artifacts()
    tok = art.corpus.tokenizer
    prompts = eval_prompts(art.corpus, n_eval)
    texts = [p for p, _, _, _ in prompts]
    golds = [g for _, g, _, _ in prompts]
    rows = []
    for frac in fractions:
        n = max(8, int(len(art.corpus.train) * frac))
        if frac == 1.0:
            params = art.params_mask   # reuse the cached full model
        else:
            params, _ = train_model(
                art.cfg, art.corpus,
                TrainConfig(epochs=epochs, batch_size=8, seq_len=256,
                            causal=False, max_examples=n))
        eng = MedVerseEngine(params, art.cfg, tok,
                             default_engine_cfg(max_slots=8))
        rp = eng.generate(texts)
        acc = accuracy(rp, golds)
        plan_rate = sum(r.plan_ok for r in rp) / len(rp)
        rows.append((frac, n, acc, plan_rate))
        emit(f"table6_frac{int(frac*100)}", 0.0,
             f"n={n};acc={acc:.3f};plan_ok={plan_rate:.2f}")
    return rows


if __name__ == "__main__":
    run()
