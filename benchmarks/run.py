"""Benchmark runner — one harness per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. Run as:
    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--fast]
or directly (the CI smoke gate does this):
    PYTHONPATH=src python benchmarks/run.py --smoke
"""

from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

if __package__ in (None, ""):  # direct script execution
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    __package__ = "benchmarks"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single benchmark by module name")
    ap.add_argument("--fast", action="store_true",
                    help="smaller eval subsets")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: fig4a/fig4b on a tiny config for a few "
                         "tokens; asserts completion, not numbers")
    args = ap.parse_args()

    from . import (
        fig4a_latency,
        table1_accuracy,
        fig4b_throughput,
        kernel_bench,
        roofline,
        serving_bench,
        spec_bench,
        table2_cost_decomp,
        table3_topology,
        table4_reliability,
        table5_ablation,
        table6_data_scale,
        table8_train_infer,
    )
    from .common import get_artifacts

    if args.smoke:
        art = get_artifacts(n_items=60, epochs=1, tag="smoke")
        benches = {
            "kernel_bench": lambda a: kernel_bench.run(smoke=True),
            "fig4a_latency": lambda a: fig4a_latency.run(a, n_per_class=1),
            "fig4b_throughput": lambda a: fig4b_throughput.run(
                a, lengths=(32,)),
            "serving_bench": lambda a: serving_bench.run(a, smoke=True),
            "spec_bench": lambda a: spec_bench.run(a, smoke=True),
        }
        failures = 0
        for name, fn in benches.items():
            print(f"# === {name} (smoke) ===", flush=True)
            t0 = time.time()
            try:
                fn(art)
            except Exception as e:
                failures += 1
                print(f"{name},0.0,ERROR={type(e).__name__}:{e}")
                traceback.print_exc()
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        sys.exit(1 if failures else 0)

    benches = {
        "roofline": lambda a: roofline.run(),
        "kernel_bench": lambda a: kernel_bench.run(),
        "fig4a_latency": lambda a: fig4a_latency.run(a, n_per_class=2 if args.fast else 4),
        "fig4b_throughput": lambda a: fig4b_throughput.run(
            a, lengths=(64, 128) if args.fast else (64, 128, 256, 512)),
        "serving_bench": lambda a: serving_bench.run(
            a, n_requests=8 if args.fast else 16),
        "spec_bench": lambda a: spec_bench.run(
            a, n_unique=2 if args.fast else 4,
            n_repeats=3 if args.fast else 4),
        "table1_accuracy": lambda a: table1_accuracy.run(a, n=12 if args.fast else 24),
        "table2_cost_decomp": lambda a: table2_cost_decomp.run(a, n=4 if args.fast else 8),
        "table3_topology": lambda a: table3_topology.run(a, n_per_class=2 if args.fast else 4),
        "table4_reliability": lambda a: table4_reliability.run(a, n=8 if args.fast else 16),
        "table5_ablation": lambda a: table5_ablation.run(a, n=6 if args.fast else 12),
        "table6_data_scale": lambda a: table6_data_scale.run(
            a, fractions=(0.5, 1.0) if args.fast else (0.25, 0.5, 1.0)),
        "table8_train_infer": lambda a: table8_train_infer.run(a, n=12 if args.fast else 24),
    }
    if args.only:
        benches = {args.only: benches[args.only]}

    art = None
    needs_model = set(benches) - {"roofline", "kernel_bench"}
    if needs_model:
        art = get_artifacts()

    failures = 0
    for name, fn in benches.items():
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            fn(art)
        except Exception as e:
            failures += 1
            print(f"{name},0.0,ERROR={type(e).__name__}:{e}")
            traceback.print_exc()
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
