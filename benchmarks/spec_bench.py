"""Speculative-decoding benchmark: decode iterations + acceptance.

Serves a repeated-request workload (the regime both drafters target:
medical triage traffic re-asks near-identical questions) three times
through the paged engine — speculation off, ngram drafter, radix
drafter — one request at a time at temperature 0, and measures the
*deterministic* outcomes: decode iterations to drain the workload,
draft acceptance rate, committed tokens per step. Wall time is never
recorded; every gated number is a step/count metric, reproducible
across machines on a given commit.

Asserts the correctness contract in-bench: output text is bit-identical
across all three runs, both drafters finish in strictly fewer decode
iterations than the baseline, and the page allocator returns to its
pre-workload level. Writes ``results/BENCH_spec.json`` (committed
baseline under ``benchmarks/baselines/``, gated by
``check_regression.py``).
"""

from __future__ import annotations

import json
import os
import sys

if __package__ in (None, ""):  # direct script execution
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    __package__ = "benchmarks"

from .common import default_engine_cfg, emit, eval_prompts, get_artifacts
from repro.engine import MedVerseEngine

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

DRAFT_LEN = 4


def _workload(art, n_unique: int, n_repeats: int):
    """(prompt, plan) pairs: ``n_unique`` distinct eval questions with
    teacher-forced plans, each repeated ``n_repeats`` times
    back-to-back — repeats are where lookup drafters earn their keep."""
    base = [(p, plan) for p, _, plan, _ in eval_prompts(art.corpus,
                                                        n=n_unique)]
    return [pair for pair in base for _ in range(n_repeats)]


def _run_engine(art, workload, ecfg):
    """Drain the workload one request at a time; return per-run stats
    and the concatenated output texts (the parity witness)."""
    eng = MedVerseEngine(art.params_mask, art.cfg, art.corpus.tokenizer,
                         ecfg)
    eng.warmup()
    used0 = eng.alloc.used
    texts = []
    for prompt, plan in workload:
        res = eng.generate([prompt], plans=[plan])[0]
        texts.append(res.text)
    assert eng.alloc.used == used0, (
        f"leaked pages: used {eng.alloc.used} vs {used0} pre-workload")
    s = eng.spec_stats
    tokens = sum(len(t.split()) for t in texts)  # proxy; iters is the gate
    return {
        "decode_iters": eng.total_iters,
        "tokens": s["tokens"] if s["steps"] else tokens,
        "proposed": s["proposed"],
        "accepted": s["accepted"],
        "acceptance": (s["accepted"] / s["proposed"]
                       if s["proposed"] else None),
        "forced_batched": s["forced_batched"],
        "tokens_per_step": (s["tokens"] / s["steps"]
                            if s["steps"] else None),
    }, texts


def run(art=None, n_unique: int = 4, n_repeats: int = 4,
        smoke: bool = False):
    if smoke:
        n_unique, n_repeats = 2, 4
        art = art or get_artifacts(n_items=60, epochs=1, tag="smoke")
    art = art or get_artifacts()
    workload = _workload(art, n_unique, n_repeats)

    def ecfg(**kw):
        return default_engine_cfg(
            max_slots=8, n_pages=4096, max_step_tokens=8,
            max_conclusion_tokens=8, draft_len=DRAFT_LEN, **kw)

    runs = {}
    base_stats, base_texts = _run_engine(art, workload, ecfg())
    runs["off"] = {"decode_iters": base_stats["decode_iters"]}
    emit("spec_off", 0.0, f"iters={base_stats['decode_iters']}")
    for name in ("ngram", "radix"):
        stats, texts = _run_engine(
            art, workload, ecfg(speculative=True, drafter=name))
        assert texts == base_texts, (
            f"{name}: speculative output diverged from baseline")
        assert stats["decode_iters"] < base_stats["decode_iters"], (
            f"{name}: {stats['decode_iters']} iters, no better than "
            f"baseline {base_stats['decode_iters']}")
        stats["iters_saved"] = (base_stats["decode_iters"]
                                - stats["decode_iters"])
        runs[name] = stats
        emit(f"spec_{name}", 0.0,
             f"iters={stats['decode_iters']};"
             f"saved={stats['iters_saved']};"
             f"acceptance={stats['acceptance']:.2f};"
             f"tok_step={stats['tokens_per_step']:.2f}")
        print(f"# {name}: {stats['decode_iters']} iters "
              f"(off={base_stats['decode_iters']}), accepted "
              f"{stats['accepted']}/{stats['proposed']} drafts "
              f"({stats['acceptance']:.0%}), "
              f"{stats['tokens_per_step']:.2f} tok/step")

    os.makedirs(RESULTS, exist_ok=True)
    out = {"config": {"smoke": smoke, "n_unique": n_unique,
                      "n_repeats": n_repeats,
                      "n_requests": len(workload),
                      "draft_len": DRAFT_LEN, "max_slots": 8},
           "runs": runs}
    path = os.path.join(RESULTS, "BENCH_spec.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {os.path.relpath(path)}")
    return runs


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--unique", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=4)
    args = ap.parse_args()
    run(n_unique=args.unique, n_repeats=args.repeats, smoke=args.smoke)
