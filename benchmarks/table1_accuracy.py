"""Table 1: accuracy comparison, MedVerse vs baselines, broken down per
"benchmark" (here: per topology class of the synthetic eval set, our
analogue of the paper's five datasets).

Paper: MedVerse lifts Qwen2.5-7B avg 34.5->39.3 and Llama-3.1-8B
42.2->44.2 over medical baselines. Our directional claim: the
MedVerse-trained + parallel-decoded configuration beats the causal
serial baseline on the synthetic eval, per class and on average.
"""

from __future__ import annotations

from collections import defaultdict

from .common import (
    default_engine_cfg,
    emit,
    extract_answer,
    get_artifacts,
)
from repro.engine import MedVerseEngine, SerialEngine


def run(art=None, n: int = 24):
    art = art or get_artifacts()
    tok = art.corpus.tokenizer
    exs = art.corpus.eval[:n]
    prompts, golds, classes = [], [], []
    for ex in exs:
        opts = " ".join(f"{l} ) {o}" for l, o in zip("abcd", ex.options))
        prompts.append(f"{ex.question} Options : {opts}")
        golds.append(ex.answer_letter)
        classes.append(ex.topology)
    ser = SerialEngine(art.params_auto, art.cfg, tok, default_engine_cfg())
    base = ser.generate(prompts, max_tokens=220)
    eng = MedVerseEngine(art.params_mask, art.cfg, tok,
                         default_engine_cfg(max_slots=8))
    ours = eng.generate(prompts)

    per_class = defaultdict(lambda: {"base": [], "ours": []})
    for r_b, r_o, g, c in zip(base, ours, golds, classes):
        per_class[c]["base"].append(int(extract_answer(r_b.text) == g))
        per_class[c]["ours"].append(int(extract_answer(r_o.text) == g))
    rows = {}
    tot_b, tot_o, tot_n = 0, 0, 0
    for c, d in sorted(per_class.items()):
        nb, no, nn = sum(d["base"]), sum(d["ours"]), len(d["base"])
        rows[c] = (nb / nn, no / nn)
        tot_b, tot_o, tot_n = tot_b + nb, tot_o + no, tot_n + nn
        emit(f"table1_{c}", 0.0,
             f"baseline_acc={nb/nn:.3f};medverse_acc={no/nn:.3f};n={nn}")
    emit("table1_average", 0.0,
         f"baseline_acc={tot_b/max(tot_n,1):.3f};"
         f"medverse_acc={tot_o/max(tot_n,1):.3f};n={tot_n}")
    return rows


if __name__ == "__main__":
    run()
