"""Table 5: efficacy of linear-to-parallel hybridization.

  Autoregressive    linear only                    (acc 18.4, 5.1s)
  Direct Petri Net  parallel only, no linear plan  (acc 17.4, 4.5s)
  MedVerse          linear planning + parallel     (acc 19.3, 4.0s)

Our Direct-Petri variant suppresses the <Think> linear stage by
injecting a bare plan skeleton and letting the model construct steps
directly; MedVerse generates its own plan (Phase I) then executes.
"""

from __future__ import annotations

import time

from .common import (
    accuracy,
    default_engine_cfg,
    emit,
    eval_prompts,
    get_artifacts,
)
from repro.core.plan import parse_plan
from repro.engine import MedVerseEngine, SerialEngine


def run(art=None, n: int = 12):
    art = art or get_artifacts()
    tok = art.corpus.tokenizer
    prompts = eval_prompts(art.corpus, n)
    texts = [p for p, _, _, _ in prompts]
    golds = [g for _, g, _, _ in prompts]
    rows = {}
    # (a) serial AR
    ser = SerialEngine(art.params_auto, art.cfg, tok, default_engine_cfg())
    t0 = time.monotonic()
    rs = ser.generate(texts, max_tokens=220)
    rows["autoregressive"] = (accuracy(rs, golds),
                              (time.monotonic() - t0) / n)
    # (b) direct petri: plan skeleton WITHOUT the linear <Think> stage
    accs, dt = [], 0.0
    eng_d = MedVerseEngine(art.params_mask, art.cfg, tok,
                           default_engine_cfg())
    for (prompt, gold, plan, _), g in zip(prompts, golds):
        bare = plan[plan.find("<Plan>"):]  # strip the linear Think phase
        t0 = time.monotonic()
        r = eng_d.generate([prompt], plans=[bare])[0]
        dt += time.monotonic() - t0
        accs.append(r)
    rows["direct_petri"] = (accuracy(accs, golds), dt / n)
    # (c) MedVerse: model-generated plan (Phase I) + parallel execution
    eng = MedVerseEngine(art.params_mask, art.cfg, tok,
                         default_engine_cfg(max_slots=8))
    t0 = time.monotonic()
    rp = eng.generate(texts)
    rows["medverse"] = (accuracy(rp, golds), (time.monotonic() - t0) / n)
    for k, (acc, lat) in rows.items():
        emit(f"table5_{k}", lat * 1e6, f"acc={acc:.3f};latency_s={lat:.2f}")
    return rows


if __name__ == "__main__":
    run()
