"""Table 8 (and the accuracy core of Table 1): the 2x2 ablation of
training strategy x inference mode.

  Auto-Ser  causal-trained,   serial decode          (baseline)
  Auto-Par  causal-trained,   DAG-parallel engine
  Mask-Ser  MedVerse-trained, serial decode
  Mask-Par  MedVerse-trained, DAG-parallel engine    (MedVerse)

Paper: 36.9 / 37.9 / 38.6 / 39.3 — Mask-Par best, monotone. We report
answer accuracy on the held-out synthetic eval set plus plan validity
for the Par modes (absolute values differ from the paper — synthetic
teacher; the *ordering* is the claim under validation, DESIGN.md §6).
"""

from __future__ import annotations

from .common import (
    accuracy,
    default_engine_cfg,
    emit,
    eval_prompts,
    extract_answer,
    get_artifacts,
)
from repro.engine import MedVerseEngine, SerialEngine


def run(art=None, n: int = 24):
    art = art or get_artifacts()
    tok = art.corpus.tokenizer
    prompts = eval_prompts(art.corpus, n)
    texts = [p for p, _, _, _ in prompts]
    golds = [g for _, g, _, _ in prompts]
    results = {}
    for train_tag, params in (("Auto", art.params_auto),
                              ("Mask", art.params_mask)):
        ser = SerialEngine(params, art.cfg, tok, default_engine_cfg())
        rs = ser.generate(texts, max_tokens=220)
        results[f"{train_tag}-Ser"] = (accuracy(rs, golds), None)
        eng = MedVerseEngine(params, art.cfg, tok,
                             default_engine_cfg(max_slots=8))
        rp = eng.generate(texts)
        plan_rate = sum(r.plan_ok for r in rp) / len(rp)
        results[f"{train_tag}-Par"] = (accuracy(rp, golds), plan_rate)
    for k, (acc, pr) in results.items():
        extra = f";plan_ok={pr:.2f}" if pr is not None else ""
        emit(f"table8_{k}", 0.0, f"acc={acc:.3f}{extra}")
    return results


if __name__ == "__main__":
    run()
