"""Table 2: wall-clock decomposition — planning %, execution %,
scheduling/parsing overhead %, fork/join cost %.

Paper: planning 39%, execution 61%, system overhead <0.01%, KV
fork/join 1.1%. We report the same four rows from the engine's
per-request timing instrumentation.
"""

from __future__ import annotations

from .common import default_engine_cfg, emit, eval_prompts, get_artifacts
from repro.engine import MedVerseEngine


def run(art=None, n: int = 8):
    art = art or get_artifacts()
    tok = art.corpus.tokenizer
    prompts = eval_prompts(art.corpus, n)
    totals = {"planning": 0.0, "execution": 0.0, "conclusion": 0.0,
              "fork_join": 0.0, "schedule_parse": 0.0}
    eng = MedVerseEngine(art.params_mask, art.cfg, tok,
                         default_engine_cfg())
    for prompt, _, plan, _ in prompts:
        r = eng.generate([prompt], plans=[plan])[0]
        for k in totals:
            totals[k] += r.timings.get(k, 0.0)
    total = sum(totals[k] for k in ("planning", "execution", "conclusion"))
    rows = []
    for k in ("planning", "execution", "conclusion"):
        pct = 100 * totals[k] / max(total, 1e-9)
        rows.append((k, pct))
        emit(f"table2_{k}", totals[k] / n * 1e6, f"pct={pct:.1f}%")
    for k in ("schedule_parse", "fork_join"):
        pct = 100 * totals[k] / max(total, 1e-9)
        rows.append((k, pct))
        emit(f"table2_{k}", totals[k] / n * 1e6, f"pct={pct:.3f}%")
    return rows


if __name__ == "__main__":
    run()
