"""Figure 4a: end-to-end latency, MedVerse (parallel) vs serial AR.

The paper measures wall-clock per query across datasets. We measure
per-topology-class subsets of the synthetic eval set, generating the
same curated reasoning content through (a) the MedVerse engine (plan
injected, steps decoded in parallel frontiers) and (b) a serial engine
forced to decode the same number of tokens. Speedup = serial / parallel.
"""

from __future__ import annotations

import time
from collections import defaultdict

from .common import (
    default_engine_cfg,
    emit,
    eval_prompts,
    get_artifacts,
)
from repro.engine import EngineConfig, MedVerseEngine, SerialEngine


def run(art=None, n_per_class: int = 4):
    art = art or get_artifacts()
    tok = art.corpus.tokenizer
    by_class = defaultdict(list)
    for ex in art.corpus.eval:
        by_class[ex.topology].append(ex)
    eng = MedVerseEngine(art.params_mask, art.cfg, tok,
                         default_engine_cfg(max_slots=8))
    ser = SerialEngine(art.params_auto, art.cfg, tok,
                       default_engine_cfg(max_slots=8))
    # warm the jit caches so neither side pays compilation in the timing
    warm = art.corpus.eval[0]
    wopts = " ".join(f"{l} ) {o}" for l, o in zip("abcd", warm.options))
    wp = f"{warm.question} Options : {wopts}"
    eng.generate([wp], plans=[warm.prefix_text[len(wp):].strip()])
    ser.generate([wp], max_tokens=8)
    rows = []
    for topo_class, exs in sorted(by_class.items()):
        exs = exs[:n_per_class]
        if not exs:
            continue
        par_wall = ser_wall = 0.0
        par_tok = ser_tok = 0
        for ex in exs:
            opts = " ".join(f"{l} ) {o}" for l, o in zip("abcd", ex.options))
            prompt = f"{ex.question} Options : {opts}"
            plan = ex.prefix_text[len(prompt):].strip()
            t0 = time.monotonic()
            r = eng.generate([prompt], plans=[plan])[0]
            par_wall += time.monotonic() - t0
            par_tok += r.n_tokens
            t0 = time.monotonic()
            s = ser.generate([prompt], max_tokens=r.n_tokens)[0]
            ser_wall += time.monotonic() - t0
            ser_tok += s.n_tokens
        speedup = ser_wall / max(par_wall, 1e-9)
        rows.append((topo_class, par_wall / len(exs), ser_wall / len(exs),
                     speedup))
        emit(f"fig4a_latency_{topo_class}",
             par_wall / len(exs) * 1e6,
             f"serial_s={ser_wall/len(exs):.3f};speedup={speedup:.2f}x;"
             f"iso_tokens={par_tok}")
    return rows


if __name__ == "__main__":
    run()
