"""Roofline aggregation: reads results/dryrun/*.json (written by
repro.launch.dryrun) and renders the §Roofline table — three terms per
(arch x shape), dominant bottleneck, MODEL_FLOPS / HLO_FLOPs ratio, and
a one-line "what moves the dominant term" note."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

NOTES = {
    ("compute", "train"): "more chips / lower precision matmuls",
    ("compute", "decode"): "batch more streams per chip",
    ("memory", "train"): "flash/chunked attention + fewer remat passes",
    ("memory", "prefill"): "flash/chunked attention (O(S) not O(S^2) traffic)",
    ("memory", "decode"): "KV-cache dtype (bf16->int8) or MQA/MLA compression",
    ("collective", "train"): "shard FSDP gather over pod-local links; overlap",
    ("collective", "decode"): "replicate small params instead of TP gathers",
}


def load(mesh: str = "16_16", unrolled: bool = True) -> List[Dict]:
    rows = []
    suffix = "__unrolled" if unrolled else ""
    for fn in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}{suffix}.json"))):
        if not unrolled and "__unrolled" in fn:
            continue
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def load_merged(mesh: str = "16_16") -> List[Dict]:
    """Unrolled (honest-FLOPs) records where available; scanned records
    otherwise, marked measured='scanned' (cost_analysis counts scan
    bodies once — the scan-count caveat, EXPERIMENTS.md §Dry-run)."""
    unrolled = {(r["arch"], r["shape"]): r for r in load(mesh, True)}
    merged = []
    for r in load(mesh, False):
        key = (r["arch"], r["shape"])
        if key in unrolled:
            u = unrolled[key]
            u["measured"] = "unrolled"
            merged.append(u)
        else:
            r["measured"] = "scanned*"
            merged.append(r)
    return merged


def shape_kind(shape: str) -> str:
    return {"train_4k": "train", "prefill_32k": "prefill"}.get(shape, "decode")


def render(rows: List[Dict]) -> str:
    hdr = (f"{'arch':<20} {'shape':<12} {'compute_s':>10} {'memory_s':>10} "
           f"{'coll_s':>10} {'dominant':>10} {'useful':>7} {'meas':>8}  next-step")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(f"{r['arch']:<20} {r['shape']:<12} "
                         f"{'skipped (DESIGN.md §4)':^40}")
            continue
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:<20} {r['shape']:<12} ERROR")
            continue
        rf = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        note = NOTES.get((rf["dominant"], shape_kind(r["shape"])), "")
        lines.append(
            f"{r['arch']:<20} {r['shape']:<12} {rf['compute_s']:>10.3e} "
            f"{rf['memory_s']:>10.3e} {rf['collective_s']:>10.3e} "
            f"{rf['dominant']:>10} "
            f"{ratio if ratio is None else round(ratio, 3)!s:>7} "
            f"{r.get('measured', ''):>8}  {note}"
        )
    return "\n".join(lines)


def run():
    rows = load_merged()
    print(render(rows))
    # CSV emission for the harness contract
    for r in rows:
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        tot = rf["compute_s"] + rf["memory_s"] + rf["collective_s"]
        print(f"roofline_{r['arch']}_{r['shape']},{tot*1e6:.1f},"
              f"dominant={rf['dominant']};compute_s={rf['compute_s']:.3e};"
              f"memory_s={rf['memory_s']:.3e};"
              f"collective_s={rf['collective_s']:.3e}")
    return rows


if __name__ == "__main__":
    run()
